//! Streaming and batch statistics used by metrics and the bench harness.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than 2 samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let total = n1 + n2;
        self.mean += delta * n2 / total;
        self.m2 += other.m2 + delta * delta * n1 * n2 / total;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Bucket count of [`WaitHistogram`] (fixed so it serializes as a flat
/// 8-element array over the wire).
pub const WAIT_HIST_BUCKETS: usize = 8;

/// Upper bucket bounds in seconds — half-decade log scale from 1 ms to
/// 1 s; the 8th bucket is unbounded (waits above 1 s are an SLO breach
/// whichever decade they land in).
pub const WAIT_HIST_BOUNDS: [f64; WAIT_HIST_BUCKETS - 1] =
    [0.001, 0.003_162, 0.01, 0.031_62, 0.1, 0.316_2, 1.0];

/// Fixed 8-bucket log-scale histogram of queue-wait seconds.
///
/// Small enough to ship per tenant inside the manager's `stats` RPC
/// payload, precise enough for p50/p90 SLO checks without retaining raw
/// samples. Quantiles are *conservative*: [`WaitHistogram::quantile`]
/// returns the upper bound of the bucket the quantile lands in, so the
/// true value is never larger than reported.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WaitHistogram {
    counts: [u64; WAIT_HIST_BUCKETS],
}

impl WaitHistogram {
    pub fn new() -> WaitHistogram {
        WaitHistogram::default()
    }

    /// Record one wait (seconds). Values at or below the first bound
    /// land in bucket 0; values above the last bound land in the
    /// overflow bucket.
    pub fn record(&mut self, secs: f64) {
        let idx = WAIT_HIST_BOUNDS
            .iter()
            .position(|&bound| secs <= bound)
            .unwrap_or(WAIT_HIST_BUCKETS - 1);
        self.counts[idx] += 1;
    }

    /// The raw bucket counts (wire encode).
    pub fn counts(&self) -> &[u64; WAIT_HIST_BUCKETS] {
        &self.counts
    }

    /// Samples recorded.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Add another histogram's buckets into this one.
    pub fn merge(&mut self, other: &WaitHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
    }

    /// Rebuild from serialized bucket counts (wire decode); `None`
    /// unless exactly [`WAIT_HIST_BUCKETS`] counts are supplied.
    pub fn from_counts(counts: &[u64]) -> Option<WaitHistogram> {
        if counts.len() != WAIT_HIST_BUCKETS {
            return None;
        }
        let mut h = WaitHistogram::default();
        h.counts.copy_from_slice(counts);
        Some(h)
    }

    /// Conservative quantile estimate: the upper bound of the bucket
    /// where the cumulative count first reaches `ceil(q * total)`. An
    /// empty histogram reports 0; a quantile landing in the overflow
    /// bucket reports `f64::INFINITY` (all that is known is "> 1 s").
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = (q * total as f64).ceil().max(1.0) as u64;
        let mut cumulative = 0u64;
        for (i, &count) in self.counts.iter().enumerate() {
            cumulative += count;
            if cumulative >= rank {
                return if i < WAIT_HIST_BOUNDS.len() {
                    WAIT_HIST_BOUNDS[i]
                } else {
                    f64::INFINITY
                };
            }
        }
        f64::INFINITY
    }

    /// Median upper bound.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// 90th-percentile upper bound.
    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }
}

/// Batch summary with exact percentiles (sorts a copy).
#[derive(Debug, Clone)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty slice");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut st = OnlineStats::new();
        for &x in samples {
            st.push(x);
        }
        Summary {
            count: sorted.len(),
            mean: st.mean(),
            std_dev: st.std_dev(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut st = OnlineStats::new();
        for &x in &xs {
            st.push(x);
        }
        assert!((st.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic dataset = 32/7
        assert!((st.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(st.min(), 2.0);
        assert_eq!(st.max(), 9.0);
    }

    #[test]
    fn merge_equals_concat() {
        let a_data = [1.0, 2.0, 3.0];
        let b_data = [10.0, 20.0, 30.0, 40.0];
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        let mut whole = OnlineStats::new();
        for &x in &a_data {
            a.push(x);
            whole.push(x);
        }
        for &x in &b_data {
            b.push(x);
            whole.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
        assert_eq!(a.count(), 7);
    }

    #[test]
    fn percentiles() {
        let sorted: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&sorted, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 1.0) - 100.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 0.5) - 50.5).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        let _ = Summary::of(&[]);
    }

    #[test]
    fn wait_histogram_buckets_and_quantiles() {
        let mut h = WaitHistogram::new();
        assert_eq!(h.quantile(0.9), 0.0, "empty histogram reports 0");
        // 9 fast samples in the 1 ms bucket, 1 slow one at ~200 ms
        for _ in 0..9 {
            h.record(0.000_5);
        }
        h.record(0.2);
        assert_eq!(h.total(), 10);
        assert_eq!(h.counts()[0], 9);
        assert!((h.p50() - 0.001).abs() < 1e-12);
        // p90 rank = 9 -> still the fast bucket; p91+ crosses into slow
        assert!((h.p90() - 0.001).abs() < 1e-12);
        assert!((h.quantile(0.95) - 0.316_2).abs() < 1e-12);
        // overflow bucket is reported as unbounded
        h.record(5.0);
        assert_eq!(h.quantile(1.0), f64::INFINITY);
    }

    #[test]
    fn wait_histogram_merge_and_wire_counts() {
        let mut a = WaitHistogram::new();
        a.record(0.0005);
        a.record(0.05);
        let mut b = WaitHistogram::new();
        b.record(0.05);
        b.record(2.0);
        a.merge(&b);
        assert_eq!(a.total(), 4);
        let back = WaitHistogram::from_counts(&a.counts()[..]).unwrap();
        assert_eq!(back, a);
        assert!(WaitHistogram::from_counts(&[1, 2, 3]).is_none());
    }

    #[test]
    fn wait_histogram_boundary_values() {
        let mut h = WaitHistogram::new();
        h.record(0.001); // exactly the first bound -> bucket 0
        h.record(1.0); // exactly the last bound -> bucket 6
        h.record(1.000_001); // just above -> overflow
        h.record(-0.5); // negative clock skew clamps to bucket 0
        let c = h.counts();
        assert_eq!((c[0], c[6], c[7]), (2, 1, 1));
    }
}
