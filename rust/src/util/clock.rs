//! Wall-clock vs virtual time behind one trait.
//!
//! Every time-dependent component (heartbeat monitor, scheduler, metrics)
//! takes a [`Clock`] so the same code runs in real time (production path,
//! [`SystemClock`]) and in simulated time (figure regeneration via the
//! discrete-event simulator, [`VirtualClock`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A monotonic clock measured in seconds since an arbitrary origin.
pub trait Clock: Send + Sync {
    /// Seconds since the clock origin.
    fn now(&self) -> f64;
}

/// Real wall-clock time (monotonic).
pub struct SystemClock {
    origin: Instant,
}

impl SystemClock {
    pub fn new() -> Self {
        SystemClock { origin: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SystemClock {
    fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }
}

/// Manually-advanced virtual time, shared across threads.
///
/// Stored as integer nanoseconds so concurrent `advance_to` calls stay
/// monotonic without locks.
#[derive(Clone)]
pub struct VirtualClock {
    nanos: Arc<AtomicU64>,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { nanos: Arc::new(AtomicU64::new(0)) }
    }

    /// Advance by `dt` seconds.
    pub fn advance(&self, dt: f64) {
        assert!(dt >= 0.0, "cannot advance time backwards");
        self.nanos.fetch_add((dt * 1e9) as u64, Ordering::SeqCst);
    }

    /// Advance to an absolute time (no-op if already past it).
    pub fn advance_to(&self, t: f64) {
        let target = (t * 1e9) as u64;
        let mut cur = self.nanos.load(Ordering::SeqCst);
        while cur < target {
            match self.nanos.compare_exchange(cur, target, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.nanos.load(Ordering::SeqCst) as f64 / 1e9
    }
}

/// Format a duration of seconds human-readably ("1m23.4s", "45.6ms").
pub fn fmt_secs(secs: f64) -> String {
    if secs >= 60.0 {
        let m = (secs / 60.0).floor() as u64;
        format!("{m}m{:.1}s", secs - 60.0 * m as f64)
    } else if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}us", secs * 1e6)
    }
}

/// Sleep helper usable with either clock flavor in tests.
pub fn sleep(d: Duration) {
    std::thread::sleep(d);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_is_monotonic() {
        let c = SystemClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.advance_to(10.0);
        assert!((c.now() - 10.0).abs() < 1e-9);
        c.advance_to(5.0); // no-op, already past
        assert!((c.now() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn virtual_clock_clones_share_state() {
        let a = VirtualClock::new();
        let b = a.clone();
        a.advance(2.0);
        assert!((b.now() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(90.0), "1m30.0s");
        assert_eq!(fmt_secs(1.5), "1.50s");
        assert_eq!(fmt_secs(0.0123), "12.30ms");
        assert_eq!(fmt_secs(0.000_045), "45.00us");
    }
}
