//! Scoped worker-thread pool helper (std-only; DESIGN.md §11).
//!
//! One primitive serves every data-parallel hot path in the crate — the
//! parallel bank executor (`model::exec::ParallelQsimExecutor`) and the
//! shot engine (`qsim::shots::run_shots`): evaluate an index-addressed
//! function across scoped OS threads and return the results in index
//! order, bitwise identical to the serial evaluation.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Evaluate `f(i)` for every `i in 0..n` across up to `threads` scoped
/// OS threads; returns the results in index order.
///
/// Threads claim indices from a shared atomic cursor, which keeps the
/// pool work-conserving under OS scheduling jitter. `threads <= 1` (or
/// `n <= 1`) runs inline on the caller with no thread or lock overhead.
/// The output never depends on the thread count — only wall-clock does —
/// so `f` must not depend on evaluation order.
pub fn parallel_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                *slots[i].lock().expect("pool slot poisoned") = Some(f(i));
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().expect("pool slot poisoned").expect("pool slot unfilled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        let out = parallel_indexed(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn matches_serial_for_any_thread_count() {
        let serial = parallel_indexed(37, 1, |i| i as u64 * 3 + 1);
        for threads in [2usize, 5, 64] {
            assert_eq!(parallel_indexed(37, threads, |i| i as u64 * 3 + 1), serial);
        }
    }

    #[test]
    fn degenerate_sizes() {
        assert!(parallel_indexed(0, 4, |i| i).is_empty());
        assert_eq!(parallel_indexed(1, 8, |i| i + 41), vec![41]);
    }

    #[test]
    fn every_index_evaluated_exactly_once() {
        use std::sync::atomic::AtomicU64;
        let calls = AtomicU64::new(0);
        let out = parallel_indexed(500, 3, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(out.len(), 500);
        assert_eq!(calls.load(Ordering::Relaxed), 500);
    }
}
