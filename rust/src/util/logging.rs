//! Minimal leveled logger (std-only substrate for `log`/`tracing`).
//!
//! Global level filter + timestamped, target-tagged lines on stderr.
//! The `log_*!` macros are exported at the crate root.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Trace => "TRACE",
            Level::Debug => "DEBUG",
            Level::Info => "INFO",
            Level::Warn => "WARN",
            Level::Error => "ERROR",
        }
    }

    pub fn from_str_loose(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "trace" => Some(Level::Trace),
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global level filter.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Would a record at `level` be emitted?
pub fn log_enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Emit one record. Prefer the `log_*!` macros.
pub fn log_record(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !log_enabled(level) {
        return;
    }
    let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let secs = now.as_secs();
    let millis = now.subsec_millis();
    eprintln!("[{secs}.{millis:03} {:5} {target}] {msg}", level.as_str());
}

#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log_record($crate::util::Level::Trace, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log_record($crate::util::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log_record($crate::util::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log_record($crate::util::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log_record($crate::util::Level::Error, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Trace < Level::Debug);
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn parse_levels() {
        assert_eq!(Level::from_str_loose("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::from_str_loose("warning"), Some(Level::Warn));
        assert_eq!(Level::from_str_loose("nope"), None);
    }

    #[test]
    fn filter_respects_level() {
        set_level(Level::Warn);
        assert!(!log_enabled(Level::Info));
        assert!(log_enabled(Level::Warn));
        assert!(log_enabled(Level::Error));
        set_level(Level::Info); // restore default for other tests
    }
}
