//! Foundation utilities shared by every layer of the stack.
//!
//! Everything here is `std`-only by design (the build environment has no
//! network access to crates.io; see DESIGN.md §3): leveled logging, a
//! deterministic PRNG, wall/virtual clocks, streaming statistics, and a
//! scoped worker-thread pool ([`pool::parallel_indexed`]).

pub mod clock;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod stats;

pub use clock::{Clock, SystemClock, VirtualClock};
pub use logging::{log_enabled, set_level, Level};
pub use pool::parallel_indexed;
pub use rng::Rng;
pub use stats::{OnlineStats, Summary, WaitHistogram};
