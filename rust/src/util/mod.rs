//! Foundation utilities shared by every layer of the stack.
//!
//! Everything here is `std`-only by design (the build environment has no
//! network access to crates.io; see DESIGN.md §3): leveled logging, a
//! deterministic PRNG, wall/virtual clocks, and streaming statistics.

pub mod clock;
pub mod logging;
pub mod rng;
pub mod stats;

pub use clock::{Clock, SystemClock, VirtualClock};
pub use logging::{log_enabled, set_level, Level};
pub use rng::Rng;
pub use stats::{OnlineStats, Summary};
