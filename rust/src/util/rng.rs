//! Deterministic pseudo-random number generation.
//!
//! A from-scratch xoshiro256** generator seeded through SplitMix64 —
//! the standard construction recommended by the xoshiro authors. Every
//! stochastic component in the system (data synthesis, weight init,
//! service-time jitter, property tests) takes an explicit [`Rng`] so runs
//! are reproducible from a single seed.

/// xoshiro256** PRNG with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0, 1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's nearly-divisionless bounded sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // avoid ln(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_with(mu, sigma).exp()
    }

    /// Exponential with the given rate (mean = 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let u = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        -u.ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child generator (stable stream separation).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..10).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(3);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..20).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
