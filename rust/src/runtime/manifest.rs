//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.

use std::path::{Path, PathBuf};

use crate::circuit::QuClassiConfig;
use crate::wire::{self, Value};

/// One artifact record (mirrors `compile.model.config_meta`).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub config: QuClassiConfig,
    pub n_params: usize,
    pub n_features: usize,
    /// Fixed batch of the fidelity artifact.
    pub batch: usize,
    pub path: PathBuf,
    /// Fused parameter-shift gradient artifact.
    pub grad_path: Option<PathBuf>,
    pub grad_data_batch: usize,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .map_err(|e| format!("cannot read {}: {e} (run `make artifacts` first)", mpath.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (factored out for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let v = wire::parse(text).map_err(|e| format!("manifest json: {e}"))?;
        let arts = v.req_arr("artifacts")?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let config = QuClassiConfig::new(a.req_usize("qubits")?, a.req_usize("layers")?)?;
            let meta = ArtifactMeta {
                name: a.req_str("name")?.to_string(),
                config,
                n_params: a.req_usize("n_params")?,
                n_features: a.req_usize("n_features")?,
                batch: a.req_usize("batch")?,
                path: dir.join(a.req_str("path")?),
                grad_path: a
                    .get("grad_path")
                    .and_then(Value::as_str)
                    .map(|p| dir.join(p)),
                grad_data_batch: a
                    .get("grad_data_batch")
                    .and_then(Value::as_usize)
                    .unwrap_or(0),
            };
            // Cross-check counts against the Rust-side formulas.
            if meta.n_params != config.n_params() || meta.n_features != config.n_features() {
                return Err(format!(
                    "manifest {}: param/feature counts disagree with circuit spec",
                    meta.name
                ));
            }
            artifacts.push(meta);
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn find(&self, config: &QuClassiConfig) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.config == *config)
    }

    /// Verify every referenced HLO file exists on disk.
    pub fn verify_files(&self) -> Result<(), String> {
        for a in &self.artifacts {
            if !a.path.exists() {
                return Err(format!("missing artifact file {}", a.path.display()));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": 1, "batch": 32, "grad_data_batch": 8,
        "artifacts": [
            {"name": "quclassi_q5_l1", "qubits": 5, "layers": 1,
             "n_params": 4, "n_features": 4, "batch": 32,
             "path": "quclassi_q5_l1.hlo.txt",
             "grad_path": "quclassi_q5_l1.grad.hlo.txt", "grad_data_batch": 8}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.config, QuClassiConfig::new(5, 1).unwrap());
        assert_eq!(a.batch, 32);
        assert!(a.path.ends_with("quclassi_q5_l1.hlo.txt"));
        assert!(a.grad_path.as_ref().unwrap().ends_with("quclassi_q5_l1.grad.hlo.txt"));
    }

    #[test]
    fn find_by_config() {
        let m = Manifest::parse(Path::new("x"), SAMPLE).unwrap();
        assert!(m.find(&QuClassiConfig::new(5, 1).unwrap()).is_some());
        assert!(m.find(&QuClassiConfig::new(7, 1).unwrap()).is_none());
    }

    #[test]
    fn rejects_inconsistent_counts() {
        let bad = SAMPLE.replace("\"n_params\": 4", "\"n_params\": 5");
        assert!(Manifest::parse(Path::new("x"), &bad).is_err());
    }

    #[test]
    fn rejects_bad_json() {
        assert!(Manifest::parse(Path::new("x"), "{oops").is_err());
    }

    /// Against the real artifacts when they exist (built by `make artifacts`).
    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert_eq!(m.artifacts.len(), 6);
            m.verify_files().unwrap();
            for cfg in QuClassiConfig::paper_configs() {
                assert!(m.find(&cfg).is_some(), "missing artifact for {cfg:?}");
            }
        }
    }
}
