//! The PJRT engine: compile-once, execute-many over the AOT artifacts.
//!
//! Owner-thread architecture: `xla::PjRtClient` and loaded executables
//! are `Rc`-backed (`!Send`), so one dedicated thread owns them and
//! serves execution requests over a channel. The public [`PjrtEngine`]
//! handle is `Send + Sync`, cheap to clone, and implements
//! [`CircuitExecutor`] so the whole model/trainer stack can run on PJRT
//! unchanged.
//!
//! Banks of arbitrary size are split/padded to the artifact's fixed
//! batch (32): a bank of N circuits costs `ceil(N/32)` PJRT executions.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{mpsc, Arc, Mutex};

use crate::circuit::QuClassiConfig;
use crate::model::exec::{CircuitExecutor, CircuitPair};
use crate::runtime::manifest::Manifest;

// Swap for `use xla;` when the real PJRT bindings are linked (the stub
// mirrors the exact API subset used below; see DESIGN.md §3).
use super::xla_stub as xla;

enum Request {
    Execute {
        config: QuClassiConfig,
        pairs: Vec<CircuitPair>,
        resp: mpsc::Sender<Result<Vec<f32>, String>>,
    },
    /// Fused on-device parameter-shift gradients: (theta, data batch) ->
    /// (fidelities, per-sample gradients).
    Grad {
        config: QuClassiConfig,
        theta: Vec<f32>,
        data: Vec<Vec<f32>>,
        resp: mpsc::Sender<Result<(Vec<f32>, Vec<Vec<f32>>), String>>,
    },
    Stats { resp: mpsc::Sender<EngineStats> },
    Shutdown,
}

/// Execution counters (observability / benches).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub executions: u64,
    pub circuits: u64,
    pub padded_circuits: u64,
}

/// Cloneable, thread-safe handle to the PJRT owner thread.
#[derive(Clone)]
pub struct PjrtEngine {
    tx: Arc<Mutex<mpsc::Sender<Request>>>,
}

impl PjrtEngine {
    /// Load every artifact in `dir` and compile it on the CPU PJRT client.
    ///
    /// Fails fast (before returning) if any module does not compile.
    pub fn load(dir: &Path) -> Result<PjrtEngine, String> {
        let manifest = Manifest::load(dir)?;
        manifest.verify_files()?;
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || owner_thread(manifest, rx, ready_tx))
            .map_err(|e| format!("spawn pjrt-engine: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| "pjrt-engine died during startup".to_string())??;
        Ok(PjrtEngine { tx: Arc::new(Mutex::new(tx)) })
    }

    fn send(&self, req: Request) -> Result<(), String> {
        self.tx
            .lock()
            .map_err(|_| "pjrt handle poisoned".to_string())?
            .send(req)
            .map_err(|_| "pjrt-engine thread gone".to_string())
    }

    /// Execute a bank of circuits (any size; padded internally).
    pub fn execute(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, String> {
        if pairs.is_empty() {
            return Ok(Vec::new());
        }
        let (resp_tx, resp_rx) = mpsc::channel();
        self.send(Request::Execute {
            config: *config,
            pairs: pairs.to_vec(),
            resp: resp_tx,
        })?;
        resp_rx.recv().map_err(|_| "pjrt-engine dropped request".to_string())?
    }

    /// Fused gradient path (L2 perf optimization; see EXPERIMENTS.md §Perf).
    pub fn execute_grad(
        &self,
        config: &QuClassiConfig,
        theta: &[f32],
        data: &[Vec<f32>],
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>), String> {
        let (resp_tx, resp_rx) = mpsc::channel();
        self.send(Request::Grad {
            config: *config,
            theta: theta.to_vec(),
            data: data.to_vec(),
            resp: resp_tx,
        })?;
        resp_rx.recv().map_err(|_| "pjrt-engine dropped request".to_string())?
    }

    pub fn stats(&self) -> EngineStats {
        let (resp_tx, resp_rx) = mpsc::channel();
        if self.send(Request::Stats { resp: resp_tx }).is_err() {
            return EngineStats::default();
        }
        resp_rx.recv().unwrap_or_default()
    }

    pub fn shutdown(&self) {
        let _ = self.send(Request::Shutdown);
    }
}

impl CircuitExecutor for PjrtEngine {
    fn execute_bank(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, crate::error::DqError> {
        Ok(self.execute(config, pairs)?)
    }

    fn describe(&self) -> String {
        "pjrt (AOT jax/pallas artifacts)".to_string()
    }
}

struct Loaded {
    exe: xla::PjRtLoadedExecutable,
    grad_exe: Option<xla::PjRtLoadedExecutable>,
    batch: usize,
    grad_data_batch: usize,
    n_params: usize,
    n_features: usize,
}

fn owner_thread(
    manifest: Manifest,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<(), String>>,
) {
    // Compile everything up front.
    let setup = (|| -> Result<HashMap<QuClassiConfig, Loaded>, String> {
        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e}"))?;
        let mut map = HashMap::new();
        for a in &manifest.artifacts {
            let compile = |path: &Path| -> Result<xla::PjRtLoadedExecutable, String> {
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or("non-utf8 path")?,
                )
                .map_err(|e| format!("parse {}: {e}", path.display()))?;
                client
                    .compile(&xla::XlaComputation::from_proto(&proto))
                    .map_err(|e| format!("compile {}: {e}", path.display()))
            };
            let exe = compile(&a.path)?;
            let grad_exe = match &a.grad_path {
                Some(p) if p.exists() => Some(compile(p)?),
                _ => None,
            };
            map.insert(
                a.config,
                Loaded {
                    exe,
                    grad_exe,
                    batch: a.batch,
                    grad_data_batch: a.grad_data_batch,
                    n_params: a.n_params,
                    n_features: a.n_features,
                },
            );
        }
        Ok(map)
    })();

    let loaded = match setup {
        Ok(map) => {
            let _ = ready.send(Ok(()));
            map
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let mut stats = EngineStats::default();
    while let Ok(req) = rx.recv() {
        match req {
            Request::Execute { config, pairs, resp } => {
                let result = execute_batched(&loaded, &config, &pairs, &mut stats);
                let _ = resp.send(result);
            }
            Request::Grad { config, theta, data, resp } => {
                let result = execute_grad(&loaded, &config, &theta, &data, &mut stats);
                let _ = resp.send(result);
            }
            Request::Stats { resp } => {
                let _ = resp.send(stats.clone());
            }
            Request::Shutdown => break,
        }
    }
}

fn execute_batched(
    loaded: &HashMap<QuClassiConfig, Loaded>,
    config: &QuClassiConfig,
    pairs: &[CircuitPair],
    stats: &mut EngineStats,
) -> Result<Vec<f32>, String> {
    let l = loaded
        .get(config)
        .ok_or_else(|| format!("no artifact for config {config:?}"))?;
    for (t, d) in pairs {
        if t.len() != l.n_params || d.len() != l.n_features {
            return Err(format!(
                "arity mismatch for {config:?}: theta {} (want {}), data {} (want {})",
                t.len(),
                l.n_params,
                d.len(),
                l.n_features
            ));
        }
    }
    let mut out = Vec::with_capacity(pairs.len());
    for chunk in pairs.chunks(l.batch) {
        let mut thetas = Vec::with_capacity(l.batch * l.n_params);
        let mut datas = Vec::with_capacity(l.batch * l.n_features);
        for (t, d) in chunk {
            thetas.extend_from_slice(t);
            datas.extend_from_slice(d);
        }
        // Pad the tail chunk by repeating the first pair.
        for _ in chunk.len()..l.batch {
            thetas.extend_from_slice(&chunk[0].0);
            datas.extend_from_slice(&chunk[0].1);
        }
        let t_lit = xla::Literal::vec1(&thetas)
            .reshape(&[l.batch as i64, l.n_params as i64])
            .map_err(|e| format!("theta literal: {e}"))?;
        let d_lit = xla::Literal::vec1(&datas)
            .reshape(&[l.batch as i64, l.n_features as i64])
            .map_err(|e| format!("data literal: {e}"))?;
        let result = l
            .exe
            .execute::<xla::Literal>(&[t_lit, d_lit])
            .map_err(|e| format!("pjrt execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("fetch result: {e}"))?;
        let fids = result
            .to_tuple1()
            .map_err(|e| format!("untuple: {e}"))?
            .to_vec::<f32>()
            .map_err(|e| format!("decode: {e}"))?;
        out.extend_from_slice(&fids[..chunk.len()]);
        stats.executions += 1;
        stats.circuits += chunk.len() as u64;
        stats.padded_circuits += (l.batch - chunk.len()) as u64;
    }
    Ok(out)
}

fn execute_grad(
    loaded: &HashMap<QuClassiConfig, Loaded>,
    config: &QuClassiConfig,
    theta: &[f32],
    data: &[Vec<f32>],
    stats: &mut EngineStats,
) -> Result<(Vec<f32>, Vec<Vec<f32>>), String> {
    let l = loaded
        .get(config)
        .ok_or_else(|| format!("no artifact for config {config:?}"))?;
    let grad_exe = l
        .grad_exe
        .as_ref()
        .ok_or_else(|| format!("no gradient artifact for {config:?}"))?;
    if theta.len() != l.n_params {
        return Err("theta arity mismatch".to_string());
    }
    let gb = l.grad_data_batch;
    let mut fids = Vec::with_capacity(data.len());
    let mut grads = Vec::with_capacity(data.len());
    for chunk in data.chunks(gb) {
        let mut flat = Vec::with_capacity(gb * l.n_features);
        for d in chunk {
            if d.len() != l.n_features {
                return Err("data arity mismatch".to_string());
            }
            flat.extend_from_slice(d);
        }
        for _ in chunk.len()..gb {
            flat.extend_from_slice(&chunk[0]);
        }
        let t_lit = xla::Literal::vec1(theta).reshape(&[l.n_params as i64]).map_err(|e| e.to_string())?;
        let d_lit = xla::Literal::vec1(&flat)
            .reshape(&[gb as i64, l.n_features as i64])
            .map_err(|e| e.to_string())?;
        let result = grad_exe
            .execute::<xla::Literal>(&[t_lit, d_lit])
            .map_err(|e| format!("pjrt grad execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| e.to_string())?;
        let (fid_lit, grad_lit) = result.to_tuple2().map_err(|e| format!("untuple2: {e}"))?;
        let fid_vec = fid_lit.to_vec::<f32>().map_err(|e| e.to_string())?;
        let grad_vec = grad_lit.to_vec::<f32>().map_err(|e| e.to_string())?;
        for (i, _) in chunk.iter().enumerate() {
            fids.push(fid_vec[i]);
            grads.push(grad_vec[i * l.n_params..(i + 1) * l.n_params].to_vec());
        }
        stats.executions += 1;
        stats.circuits += (chunk.len() * (4 * l.n_params + 1)) as u64;
    }
    Ok((fids, grads))
}
