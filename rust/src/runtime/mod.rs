//! PJRT artifact runtime — loads the AOT-compiled JAX/Pallas HLO and
//! executes circuit banks from the Rust hot path (no Python at runtime).
//!
//! * [`manifest`] — `artifacts/manifest.json` parsing + artifact
//!   discovery.
//! * [`engine`] — the PJRT engine: compiles each HLO text module once on
//!   `PjRtClient::cpu()` and serves batched executions. The xla crate's
//!   handles are `Rc`-based (not `Send`), so the engine runs on a
//!   dedicated owner thread behind a channel-based handle that *is*
//!   `Send + Sync` and implements [`crate::model::CircuitExecutor`].
//! * [`xla_stub`] — API-compatible stand-in for the `xla` bindings used
//!   in the std-only build (DESIGN.md §3); engine loads fail cleanly and
//!   workers fall back to the Rust simulator.

pub mod engine;
pub mod manifest;
pub mod xla_stub;

pub use engine::PjrtEngine;
pub use manifest::{ArtifactMeta, Manifest};
