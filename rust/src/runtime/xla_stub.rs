//! API-compatible stand-in for the `xla` PJRT bindings.
//!
//! The build environment is std-only (no crates.io access; DESIGN.md §3),
//! so [`super::engine`] compiles against this stub instead of the real
//! `xla` crate. The stub mirrors exactly the API subset the engine uses;
//! every load/compile path returns a descriptive error, which makes
//! [`crate::worker::WorkerBackend::auto`] fall back to the Rust
//! statevector backend. Linking the real bindings is a one-line change in
//! `runtime/engine.rs` (`use super::xla_stub as xla;` → `use xla;`).
//!
//! Nothing here is ever *executed* beyond the failing constructors: the
//! remaining types exist so the engine's owner-thread code typechecks
//! unchanged against either implementation.

use std::fmt;

const UNAVAILABLE: &str =
    "xla bindings not linked in this std-only build (see DESIGN.md §3); \
     the worker falls back to the Rust qsim backend";

/// Error type mirroring `xla::Error` (the engine only formats it).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Stand-in for `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    /// Mirrors `PjRtClient::cpu()`; always fails in the stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    /// Mirrors `PjRtClient::compile`; unreachable in the stub (no client
    /// can be constructed) but present so callers typecheck.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Mirrors `HloModuleProto::from_text_file`; always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    /// Mirrors `XlaComputation::from_proto`.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Mirrors `PjRtLoadedExecutable::execute`.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Mirrors `PjRtBuffer::to_literal_sync`.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::Literal`.
pub struct Literal;

impl Literal {
    /// Mirrors `Literal::vec1`.
    pub fn vec1(_xs: &[f32]) -> Literal {
        Literal
    }

    /// Mirrors `Literal::reshape`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    /// Mirrors `Literal::to_tuple1`.
    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        unavailable()
    }

    /// Mirrors `Literal::to_tuple2`.
    pub fn to_tuple2(&self) -> Result<(Literal, Literal), Error> {
        unavailable()
    }

    /// Mirrors `Literal::to_vec`.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().unwrap_err().to_string().contains("xla"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::vec1(&[1.0]).reshape(&[1]).is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
    }
}
