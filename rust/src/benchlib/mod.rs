//! Criterion-like micro-benchmark harness (std-only substrate).
//!
//! Warmup, adaptive iteration targeting a fixed measurement window,
//! outlier-robust statistics, and aligned table output. Bench binaries
//! (`rust/benches/*.rs`, `harness = false`) use this for microbenchmarks
//! and plain stdout tables for paper-figure regeneration.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    /// Max wall-clock samples collected per benchmark.
    pub max_samples: usize,
    /// Hard cap on iterations folded into one sample. The warmup-based
    /// per-iteration estimate can undershoot by orders of magnitude on an
    /// ultra-cheap closure (timer granularity, warmup-only optimization),
    /// which would size a single sample at many multiples of the whole
    /// measurement window; the cap bounds that overshoot.
    pub max_iters_per_sample: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_samples: 200,
            max_iters_per_sample: 1 << 22,
        }
    }
}

/// Quick config for very slow end-to-end benches.
impl BenchConfig {
    pub fn fast() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            max_samples: 30,
            max_iters_per_sample: 1 << 20,
        }
    }

    /// [`BenchConfig::fast`] when `DQ_BENCH_FAST` is set in the
    /// environment (the CI bench-smoke knob), the default window
    /// otherwise.
    pub fn from_env() -> BenchConfig {
        if std::env::var_os("DQ_BENCH_FAST").is_some() {
            BenchConfig::fast()
        } else {
            BenchConfig::default()
        }
    }
}

/// One benchmark's results (seconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters_per_sample: u64,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.summary.mean * 1e9
    }

    pub fn throughput_per_sec(&self) -> f64 {
        1.0 / self.summary.mean
    }
}

/// A group of benchmarks printed as one table.
pub struct Bencher {
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(config: BenchConfig) -> Bencher {
        Bencher { config, results: Vec::new() }
    }

    /// Run `f` repeatedly; `f` is one logical iteration.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + estimate the per-iteration cost.
        let warmup_start = Instant::now();
        let mut warmup_iters: u64 = 0;
        while warmup_start.elapsed() < self.config.warmup {
            f();
            warmup_iters += 1;
        }
        let est = warmup_start.elapsed().as_secs_f64() / warmup_iters.max(1) as f64;

        // Choose iterations per sample so one sample is ~1% of the window
        // (bounded below by 1 and above by the configured cap, so a
        // mis-estimated warmup cannot blow one sample past the window).
        let target_sample = self.config.measure.as_secs_f64() / 100.0;
        let iters =
            ((target_sample / est).ceil() as u64).clamp(1, self.config.max_iters_per_sample.max(1));
        let mut samples = Vec::new();
        let window = Instant::now();
        while window.elapsed() < self.config.measure && samples.len() < self.config.max_samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        if samples.is_empty() {
            // pathologically slow iteration: one forced sample
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            iters_per_sample: iters,
            summary: Summary::of(&samples),
        };
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Render the collected results as an aligned table.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>12} {:>10}\n",
            "benchmark", "mean", "p50", "p90", "samples"
        ));
        out.push_str(&"-".repeat(95));
        out.push('\n');
        for r in &self.results {
            out.push_str(&format!(
                "{:<44} {:>12} {:>12} {:>12} {:>10}\n",
                r.name,
                fmt_ns(r.summary.mean * 1e9),
                fmt_ns(r.summary.p50 * 1e9),
                fmt_ns(r.summary.p90 * 1e9),
                r.summary.count,
            ));
        }
        out
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{ns:.1} ns")
    }
}

/// Simple fixed-width table builder for paper-figure output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new(BenchConfig {
            warmup: Duration::from_millis(10),
            measure: Duration::from_millis(50),
            max_samples: 50,
            ..BenchConfig::default()
        });
        let r = b.bench("noop-ish", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.summary.mean > 0.0);
        assert!(r.summary.count >= 1);
    }

    #[test]
    fn report_contains_names() {
        let mut b = Bencher::new(BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            max_samples: 10,
            ..BenchConfig::default()
        });
        b.bench("alpha", || {
            std::hint::black_box(1 + 1);
        });
        let rep = b.report();
        assert!(rep.contains("alpha"));
    }

    #[test]
    fn iters_per_sample_is_capped() {
        // An ultra-cheap closure would estimate billions of iterations
        // per sample; the cap keeps one sample inside the window.
        let mut b = Bencher::new(BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            max_samples: 10,
            max_iters_per_sample: 64,
        });
        let r = b.bench("cheap", || {
            std::hint::black_box(1u64 + 1);
        });
        assert!(r.iters_per_sample <= 64, "cap ignored: {}", r.iters_per_sample);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("us"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["workers", "runtime(s)"]);
        t.row(&["1".into(), "94.7".into()]);
        t.row(&["4".into(), "73.1".into()]);
        let s = t.render();
        assert!(s.contains("workers"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
