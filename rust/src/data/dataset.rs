//! Dataset pipeline: cleaning, normalization, pair selection, splits.
//!
//! Mirrors the paper's preprocessing: "an initial cleaning process that
//! includes the removal of significant outliers", normalization into the
//! rotation-encoder range, then binary-pair selection for the QuClassi
//! classifier.

use std::path::Path;

use super::{mnist, synthetic};
use crate::util::Rng;

/// Image geometry (MNIST).
pub const IMG_SIDE: usize = 28;
pub const IMG_SIZE: usize = IMG_SIDE * IMG_SIDE;

/// One labeled image, pixels in [0, 1].
#[derive(Debug, Clone)]
pub struct Example {
    pub pixels: Vec<f32>,
    pub label: u8,
}

/// A labeled dataset with train/test views.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub train: Vec<Example>,
    pub test: Vec<Example>,
    /// The two classes of the binary task (class_a -> y=0, class_b -> y=1).
    pub classes: (u8, u8),
}

impl Dataset {
    /// Build the binary-pair dataset the paper's experiments use.
    ///
    /// Loads real MNIST from `mnist_dir` when present, otherwise
    /// generates the synthetic stand-in. `n_per_class` examples per
    /// class, 80/20 train/test split, outliers removed, deterministic
    /// for a seed.
    pub fn binary_pair(
        mnist_dir: Option<&Path>,
        class_a: u8,
        class_b: u8,
        n_per_class: usize,
        seed: u64,
    ) -> Dataset {
        let raw: Vec<Example> = match mnist_dir.and_then(mnist::discover) {
            Some((img, lbl)) => match mnist::load_pair(&img, &lbl) {
                Ok(all) => all,
                Err(e) => {
                    crate::log_warn!("data", "mnist load failed ({e}); using synthetic");
                    synthetic::generate(&[class_a, class_b], n_per_class * 4, seed)
                }
            },
            None => synthetic::generate(&[class_a, class_b], n_per_class * 4, seed),
        };

        // Select the pair, cap per-class counts.
        let mut a: Vec<Example> = raw.iter().filter(|e| e.label == class_a).cloned().collect();
        let mut b: Vec<Example> = raw.iter().filter(|e| e.label == class_b).cloned().collect();
        a.truncate(n_per_class);
        b.truncate(n_per_class);
        let mut examples: Vec<Example> = a.into_iter().chain(b).collect();

        // Cleaning: drop significant outliers by mean-intensity z-score.
        examples = remove_outliers(examples, 3.0);

        // Shuffle deterministically, split 80/20.
        let mut rng = Rng::new(seed ^ 0xD15EA5E);
        rng.shuffle(&mut examples);
        let n_test = (examples.len() / 5).max(1);
        let test = examples.split_off(examples.len() - n_test);
        Dataset { train: examples, test, classes: (class_a, class_b) }
    }

    /// Binary label for an example: 0.0 for class_a, 1.0 for class_b.
    pub fn target(&self, e: &Example) -> f32 {
        if e.label == self.classes.1 {
            1.0
        } else {
            0.0
        }
    }
}

/// Remove examples whose mean pixel intensity is more than `z_max`
/// standard deviations from the dataset mean (the paper's "significant
/// outliers" cleaning step).
pub fn remove_outliers(examples: Vec<Example>, z_max: f64) -> Vec<Example> {
    if examples.len() < 4 {
        return examples;
    }
    let means: Vec<f64> = examples
        .iter()
        .map(|e| e.pixels.iter().map(|&p| p as f64).sum::<f64>() / e.pixels.len() as f64)
        .collect();
    let mu = means.iter().sum::<f64>() / means.len() as f64;
    let var = means.iter().map(|m| (m - mu) * (m - mu)).sum::<f64>() / means.len() as f64;
    let sigma = var.sqrt().max(1e-12);
    examples
        .into_iter()
        .zip(means)
        .filter(|(_, m)| ((m - mu) / sigma).abs() <= z_max)
        .map(|(e, _)| e)
        .collect()
}

/// Normalize a feature vector into rotation-encoder angles [0, pi].
///
/// The encoder uses Ry/Rz rotations; mapping features into [0, pi] keeps
/// encodings injective (cos is monotone there).
pub fn to_angles(features: &[f32]) -> Vec<f32> {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &f in features {
        lo = lo.min(f);
        hi = hi.max(f);
    }
    let span = (hi - lo).max(1e-6);
    features
        .iter()
        .map(|&f| (f - lo) / span * std::f32::consts::PI)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_pair_has_both_classes_and_split() {
        let ds = Dataset::binary_pair(None, 3, 9, 40, 7);
        assert!(!ds.train.is_empty() && !ds.test.is_empty());
        let total = ds.train.len() + ds.test.len();
        assert!(total <= 80);
        // roughly 80/20
        assert!(ds.test.len() * 3 <= total && total <= ds.test.len() * 6);
        let train_has_a = ds.train.iter().any(|e| e.label == 3);
        let train_has_b = ds.train.iter().any(|e| e.label == 9);
        assert!(train_has_a && train_has_b);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Dataset::binary_pair(None, 1, 5, 20, 3);
        let b = Dataset::binary_pair(None, 1, 5, 20, 3);
        assert_eq!(a.train.len(), b.train.len());
        for (x, y) in a.train.iter().zip(b.train.iter()) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.pixels, y.pixels);
        }
    }

    #[test]
    fn targets_are_binary() {
        let ds = Dataset::binary_pair(None, 3, 6, 10, 1);
        for e in ds.train.iter().chain(ds.test.iter()) {
            let t = ds.target(e);
            assert!(t == 0.0 || t == 1.0);
            assert_eq!(t == 1.0, e.label == 6);
        }
    }

    #[test]
    fn outlier_removal_drops_extremes() {
        let mut examples: Vec<Example> = (0..20)
            .map(|i| Example { pixels: vec![0.5 + (i as f32) * 1e-4; 4], label: 0 })
            .collect();
        // one extreme outlier
        examples.push(Example { pixels: vec![1.0; 4], label: 0 });
        let cleaned = remove_outliers(examples, 3.0);
        assert_eq!(cleaned.len(), 20);
        assert!(cleaned.iter().all(|e| e.pixels[0] < 0.9));
    }

    #[test]
    fn outlier_removal_keeps_small_sets() {
        let examples: Vec<Example> =
            (0..3).map(|i| Example { pixels: vec![i as f32; 4], label: 0 }).collect();
        assert_eq!(remove_outliers(examples, 3.0).len(), 3);
    }

    #[test]
    fn to_angles_maps_into_zero_pi() {
        let angles = to_angles(&[-1.0, 0.0, 3.0]);
        assert!((angles[0] - 0.0).abs() < 1e-6);
        assert!((angles[2] - std::f32::consts::PI).abs() < 1e-6);
        assert!(angles[1] > 0.0 && angles[1] < std::f32::consts::PI);
    }

    #[test]
    fn to_angles_handles_constant_input() {
        let angles = to_angles(&[2.0, 2.0]);
        assert!(angles.iter().all(|a| a.is_finite()));
    }
}
