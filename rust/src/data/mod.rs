//! Dataset substrate: MNIST loading, synthetic fallback, preprocessing.
//!
//! The paper trains on MNIST binary pairs (3/9, 3/8, 3/6, 1/5). This
//! module provides (a) a real IDX-format parser for when MNIST files are
//! present on disk and (b) a deterministic synthetic digit generator used
//! when they are not (this build environment has no network access —
//! substitution documented in DESIGN.md §3). Both feed the same
//! [`dataset::Dataset`] pipeline: outlier removal, normalization to
//! rotation-encoder range, pair selection, splits.

pub mod dataset;
pub mod mnist;
pub mod synthetic;

pub use dataset::{Dataset, Example, IMG_SIDE, IMG_SIZE};
