//! Deterministic synthetic MNIST-like digit generator.
//!
//! Stand-in for the real MNIST download (DESIGN.md §3): 28x28 grayscale
//! digits rendered from 7x7 stroke templates, upscaled, then perturbed
//! with per-sample translation, intensity jitter, and pixel noise. The
//! classes keep MNIST-like structure (e.g. 3 vs 8 share right-side curves
//! and are the harder pair; 1 vs 5 is easy) so the paper's pair-difficulty
//! ordering is preserved.

use super::dataset::{Example, IMG_SIDE, IMG_SIZE};
use crate::util::Rng;

/// 7x7 stroke templates for digits 0-9 ('#' = ink).
const TEMPLATES: [[&str; 7]; 10] = [
    [
        " ##### ",
        "##   ##",
        "##   ##",
        "##   ##",
        "##   ##",
        "##   ##",
        " ##### ",
    ],
    [
        "   ##  ",
        "  ###  ",
        "   ##  ",
        "   ##  ",
        "   ##  ",
        "   ##  ",
        "  #### ",
    ],
    [
        " ##### ",
        "##   ##",
        "    ## ",
        "   ##  ",
        "  ##   ",
        " ##    ",
        "#######",
    ],
    [
        " ##### ",
        "##   ##",
        "     ##",
        "  #### ",
        "     ##",
        "##   ##",
        " ##### ",
    ],
    [
        "##  ## ",
        "##  ## ",
        "##  ## ",
        "#######",
        "    ## ",
        "    ## ",
        "    ## ",
    ],
    [
        "#######",
        "##     ",
        "###### ",
        "     ##",
        "     ##",
        "##   ##",
        " ##### ",
    ],
    [
        " ##### ",
        "##     ",
        "##     ",
        "###### ",
        "##   ##",
        "##   ##",
        " ##### ",
    ],
    [
        "#######",
        "     ##",
        "    ## ",
        "   ##  ",
        "  ##   ",
        "  ##   ",
        "  ##   ",
    ],
    [
        " ##### ",
        "##   ##",
        "##   ##",
        " ##### ",
        "##   ##",
        "##   ##",
        " ##### ",
    ],
    [
        " ##### ",
        "##   ##",
        "##   ##",
        " ######",
        "     ##",
        "     ##",
        " ##### ",
    ],
];

/// Render one perturbed digit image (pixels in [0, 1]).
pub fn render_digit(digit: u8, rng: &mut Rng) -> Vec<f32> {
    assert!(digit < 10);
    let template = &TEMPLATES[digit as usize];
    let mut img = vec![0.0f32; IMG_SIZE];
    // Per-sample perturbations.
    let dx = rng.index(3) as i32 - 1; // translation in [-1, 1]
    let dy = rng.index(3) as i32 - 1;
    let intensity = 0.8 + 0.2 * rng.f32(); // [0.8, 1.0)
    let scale = 3.7 + 0.3 * rng.f32(); // cell size ~ [3.7, 4.0)

    for (ty, row) in template.iter().enumerate() {
        for (tx, ch) in row.bytes().enumerate() {
            if ch != b'#' {
                continue;
            }
            // Paint the upscaled cell with soft edges.
            let cy0 = (ty as f32 * scale) as i32 + dy;
            let cx0 = (tx as f32 * scale) as i32 + dx;
            let cell = scale.ceil() as i32;
            for py in cy0..cy0 + cell {
                for px in cx0..cx0 + cell {
                    if (0..IMG_SIDE as i32).contains(&py) && (0..IMG_SIDE as i32).contains(&px) {
                        let idx = py as usize * IMG_SIDE + px as usize;
                        img[idx] = (img[idx] + intensity).min(1.0);
                    }
                }
            }
        }
    }
    // Pixel noise + occasional dead pixels.
    for px in img.iter_mut() {
        let noise = (rng.f32() - 0.5) * 0.08;
        *px = (*px + noise).clamp(0.0, 1.0);
    }
    img
}

/// Generate `n` examples of the given digit classes, interleaved.
pub fn generate(classes: &[u8], n: usize, seed: u64) -> Vec<Example> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let label = classes[i % classes.len()];
            Example { pixels: render_digit(label, &mut rng), label }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_have_right_shape_and_range() {
        let mut rng = Rng::new(1);
        for d in 0..10u8 {
            let img = render_digit(d, &mut rng);
            assert_eq!(img.len(), IMG_SIZE);
            assert!(img.iter().all(|p| (0.0..=1.0).contains(p)));
            // a digit must have meaningful ink
            let ink: f32 = img.iter().sum();
            assert!(ink > 20.0, "digit {d} too faint: {ink}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&[3, 9], 10, 42);
        let b = generate(&[3, 9], 10, 42);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.pixels, y.pixels);
        }
        let c = generate(&[3, 9], 10, 43);
        assert_ne!(a[0].pixels, c[0].pixels);
    }

    #[test]
    fn labels_interleave_classes() {
        let ex = generate(&[1, 5], 6, 7);
        let labels: Vec<u8> = ex.iter().map(|e| e.label).collect();
        assert_eq!(labels, vec![1, 5, 1, 5, 1, 5]);
    }

    #[test]
    fn classes_are_distinguishable_in_pixel_space() {
        // Mean intra-class distance must be well below inter-class
        // distance for the paper's pairs — the classifier's job must be
        // learnable.
        for (a, b) in [(3u8, 9u8), (3, 8), (3, 6), (1, 5)] {
            let xs = generate(&[a], 16, 11);
            let ys = generate(&[b], 16, 13);
            let dist = |p: &[f32], q: &[f32]| -> f32 {
                p.iter().zip(q).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
            };
            let mut intra = 0.0;
            let mut n_intra = 0;
            for i in 0..xs.len() {
                for j in i + 1..xs.len() {
                    intra += dist(&xs[i].pixels, &xs[j].pixels);
                    n_intra += 1;
                }
            }
            let mut inter = 0.0;
            let mut n_inter = 0;
            for x in &xs {
                for y in &ys {
                    inter += dist(&x.pixels, &y.pixels);
                    n_inter += 1;
                }
            }
            let intra = intra / n_intra as f32;
            let inter = inter / n_inter as f32;
            assert!(
                inter > intra * 1.2,
                "pair {a}/{b}: inter {inter} not above intra {intra}"
            );
        }
    }
}
