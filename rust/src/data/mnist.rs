//! MNIST IDX-format parser.
//!
//! Reads the classic `train-images-idx3-ubyte` / `train-labels-idx1-ubyte`
//! files (optionally the `.gz`-less raw form only — decompression is out
//! of scope; point the loader at unpacked files). Used when real MNIST is
//! available on disk; otherwise the synthetic generator stands in.

use std::fs::File;
use std::io::Read;
use std::path::Path;

use super::dataset::{Example, IMG_SIZE};

/// IDX parse error.
#[derive(Debug)]
pub enum IdxError {
    Io(std::io::Error),
    BadMagic(u32),
    Truncated,
    DimensionMismatch(String),
}

impl std::fmt::Display for IdxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IdxError::Io(e) => write!(f, "idx io error: {e}"),
            IdxError::BadMagic(m) => write!(f, "bad idx magic 0x{m:08x}"),
            IdxError::Truncated => write!(f, "idx file truncated"),
            IdxError::DimensionMismatch(s) => write!(f, "idx dimension mismatch: {s}"),
        }
    }
}

impl std::error::Error for IdxError {}

impl From<std::io::Error> for IdxError {
    fn from(e: std::io::Error) -> Self {
        IdxError::Io(e)
    }
}

fn read_u32(bytes: &[u8], off: usize) -> Result<u32, IdxError> {
    bytes
        .get(off..off + 4)
        .map(|b| u32::from_be_bytes([b[0], b[1], b[2], b[3]]))
        .ok_or(IdxError::Truncated)
}

/// Parse an IDX3 image file: magic 0x0803, dims [n, rows, cols].
pub fn parse_images(bytes: &[u8]) -> Result<Vec<Vec<f32>>, IdxError> {
    let magic = read_u32(bytes, 0)?;
    if magic != 0x0000_0803 {
        return Err(IdxError::BadMagic(magic));
    }
    let n = read_u32(bytes, 4)? as usize;
    let rows = read_u32(bytes, 8)? as usize;
    let cols = read_u32(bytes, 12)? as usize;
    if rows * cols != IMG_SIZE {
        return Err(IdxError::DimensionMismatch(format!("{rows}x{cols}, expected 28x28")));
    }
    let data = bytes.get(16..).ok_or(IdxError::Truncated)?;
    if data.len() < n * IMG_SIZE {
        return Err(IdxError::Truncated);
    }
    Ok((0..n)
        .map(|i| {
            data[i * IMG_SIZE..(i + 1) * IMG_SIZE]
                .iter()
                .map(|&b| b as f32 / 255.0)
                .collect()
        })
        .collect())
}

/// Parse an IDX1 label file: magic 0x0801, dims [n].
pub fn parse_labels(bytes: &[u8]) -> Result<Vec<u8>, IdxError> {
    let magic = read_u32(bytes, 0)?;
    if magic != 0x0000_0801 {
        return Err(IdxError::BadMagic(magic));
    }
    let n = read_u32(bytes, 4)? as usize;
    let data = bytes.get(8..).ok_or(IdxError::Truncated)?;
    if data.len() < n {
        return Err(IdxError::Truncated);
    }
    Ok(data[..n].to_vec())
}

/// Load a (images, labels) IDX pair from disk into examples.
pub fn load_pair(images_path: &Path, labels_path: &Path) -> Result<Vec<Example>, IdxError> {
    let mut img_bytes = Vec::new();
    File::open(images_path)?.read_to_end(&mut img_bytes)?;
    let mut lbl_bytes = Vec::new();
    File::open(labels_path)?.read_to_end(&mut lbl_bytes)?;
    let images = parse_images(&img_bytes)?;
    let labels = parse_labels(&lbl_bytes)?;
    if images.len() != labels.len() {
        return Err(IdxError::DimensionMismatch(format!(
            "{} images vs {} labels",
            images.len(),
            labels.len()
        )));
    }
    Ok(images
        .into_iter()
        .zip(labels)
        .map(|(pixels, label)| Example { pixels, label })
        .collect())
}

/// Standard MNIST file names under a directory, if they exist.
pub fn discover(dir: &Path) -> Option<(std::path::PathBuf, std::path::PathBuf)> {
    let img = dir.join("train-images-idx3-ubyte");
    let lbl = dir.join("train-labels-idx1-ubyte");
    if img.exists() && lbl.exists() {
        Some((img, lbl))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny valid IDX pair in memory.
    fn fake_idx(n: usize) -> (Vec<u8>, Vec<u8>) {
        let mut img = Vec::new();
        img.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        img.extend_from_slice(&(n as u32).to_be_bytes());
        img.extend_from_slice(&28u32.to_be_bytes());
        img.extend_from_slice(&28u32.to_be_bytes());
        for i in 0..n * IMG_SIZE {
            img.push((i % 256) as u8);
        }
        let mut lbl = Vec::new();
        lbl.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        lbl.extend_from_slice(&(n as u32).to_be_bytes());
        for i in 0..n {
            lbl.push((i % 10) as u8);
        }
        (img, lbl)
    }

    #[test]
    fn parses_valid_files() {
        let (img, lbl) = fake_idx(5);
        let images = parse_images(&img).unwrap();
        let labels = parse_labels(&lbl).unwrap();
        assert_eq!(images.len(), 5);
        assert_eq!(labels, vec![0, 1, 2, 3, 4]);
        assert_eq!(images[0].len(), IMG_SIZE);
        assert!((images[0][1] - 1.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_magic() {
        let (mut img, _) = fake_idx(1);
        img[3] = 0xFF;
        assert!(matches!(parse_images(&img), Err(IdxError::BadMagic(_))));
    }

    #[test]
    fn rejects_truncation() {
        let (img, lbl) = fake_idx(3);
        assert!(matches!(parse_images(&img[..100]), Err(IdxError::Truncated)));
        assert!(matches!(parse_labels(&lbl[..9]), Err(IdxError::Truncated)));
    }

    #[test]
    fn rejects_wrong_image_size() {
        let mut img = Vec::new();
        img.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        img.extend_from_slice(&1u32.to_be_bytes());
        img.extend_from_slice(&14u32.to_be_bytes());
        img.extend_from_slice(&14u32.to_be_bytes());
        img.extend(std::iter::repeat(0u8).take(196));
        assert!(matches!(parse_images(&img), Err(IdxError::DimensionMismatch(_))));
    }

    #[test]
    fn pixel_values_normalized() {
        let (img, _) = fake_idx(2);
        let images = parse_images(&img).unwrap();
        for px in images.iter().flatten() {
            assert!((0.0..=1.0).contains(px));
        }
    }
}
