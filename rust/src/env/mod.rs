//! Cloud-environment models (paper §IV): the evaluation substrate.
//!
//! The paper measures DQuLearn on (a) IBM-Q cloud simulation backends —
//! an **uncontrolled** environment with shared backends and network
//! jitter — and (b) Google Cloud e2-medium VMs — a **controlled**
//! environment with a known 1-core CPU budget per worker. Neither is
//! available here, so these models replay the *real co-Manager scheduler
//! code* (`coordinator::{Registry, scheduler}`) inside the discrete-event
//! simulator against calibrated service-time distributions (DESIGN.md §3).
//!
//! * [`calib`] — per-(qubits, layers) circuit service times; defaults are
//!   Qiskit-magnitude, and `Calibration::from_measured` accepts real
//!   per-circuit PJRT timings from this machine.
//! * [`sim`] — the cluster simulation: clients with serial submission
//!   overhead, Algorithm-2 assignment, worker service models (FIFO
//!   backends for IBM-Q, processor-sharing VMs for GCP), heartbeats,
//!   single- vs multi-tenant modes.
//! * [`scenarios`] — ready-made workloads for Figures 3-6.

pub mod calib;
pub mod scenarios;
pub mod sim;

pub use calib::Calibration;
pub use sim::{ClientJob, EnvParams, SimConfig, SimResult, SimWorkerSpec, Tenancy};
