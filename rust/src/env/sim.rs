//! The cluster simulation: clients → co-Manager (Algorithm 2) → worker
//! service models, on the discrete-event simulator.
//!
//! What is real: the Registry, the candidate filter, the CRU-ascending
//! selection — the exact code the live manager runs. What is modeled:
//! wall-clock costs (client-side serial overhead per circuit, worker
//! service times, jitter), because this testbed has one core and no
//! quantum cloud (DESIGN.md §3).

use std::collections::{BTreeMap, VecDeque};

use crate::circuit::QuClassiConfig;
use crate::coordinator::registry::{Registry, WorkerId};
use crate::coordinator::scheduler;
use crate::des::Des;
use crate::env::calib::Calibration;
use crate::util::Rng;

/// One simulated worker.
#[derive(Debug, Clone, Copy)]
pub struct SimWorkerSpec {
    pub max_qubits: usize,
    /// Relative speed (1.0 = calibration baseline).
    pub speed: f64,
    /// Reported noise estimate (0.0 = ideal backend). Only consulted
    /// when [`SimConfig::noise_aware_alpha`] is set.
    pub noise: f64,
}

/// Environment parameters.
#[derive(Debug, Clone, Copy)]
pub struct EnvParams {
    /// Client-side serial seconds per circuit (submission + quantum state
    /// analysis loop-back — Algorithm 1's classical portion).
    pub client_overhead: f64,
    /// Lognormal sigma on worker service times (uncontrolled jitter).
    pub jitter_sigma: f64,
    /// Mean extra queueing delay per circuit on shared cloud backends
    /// (exponential; 0 for a controlled environment).
    pub queue_delay_mean: f64,
    /// Processor sharing: service time scales with the number of circuits
    /// co-resident on the worker (models 1-core e2-medium VMs).
    pub cpu_share: bool,
    /// FIFO backend: the worker executes one circuit at a time (IBM-Q
    /// backends run jobs sequentially); later circuits wait in its queue.
    pub fifo: bool,
    /// CRU contributed by each co-resident circuit.
    pub cru_per_circuit: f64,
}

impl EnvParams {
    /// IBM-Q cloud backends (paper §IV-C1): uncontrolled — jitter, shared
    /// backend queueing, FIFO execution (no qubit-capacity pressure; the
    /// paper calls these "unrestricted quantum workers").
    pub fn ibmq_uncontrolled() -> EnvParams {
        EnvParams {
            client_overhead: 0.045,
            jitter_sigma: 0.35,
            queue_delay_mean: 0.010,
            cpu_share: false,
            fifo: true,
            cru_per_circuit: 0.10,
        }
    }

    /// GCP e2-medium VMs (paper §IV-C2): controlled — no external jitter,
    /// processor sharing on the single core.
    pub fn gcp_controlled() -> EnvParams {
        EnvParams {
            client_overhead: 0.045,
            jitter_sigma: 0.05,
            queue_delay_mean: 0.0,
            cpu_share: true,
            fifo: false,
            cru_per_circuit: 0.45,
        }
    }
}

/// Tenancy mode (Figure 6's comparison).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tenancy {
    /// All clients share the whole worker pool through the co-Manager.
    MultiTenant,
    /// The paper's single-tenant baseline (its IBM-Q criticism in §I):
    /// "one user occupies the entire machine while others wait in a
    /// queue" — clients get the whole pool exclusively, FIFO by client
    /// index; a waiting client's circuits are never assigned.
    SingleTenant,
}

/// A client's training job: `n_circuits` independent circuits of one
/// configuration (one epoch), submitted in rounds.
///
/// Algorithm 1 alternates phases *per sample*: build the parameter-shift
/// bank (serial classical work), execute the bank (distributed), analyze
/// results (serial) — build/analysis does not overlap worker execution.
/// `bank_size` is the circuits per round (≈ 2P per sample per filter);
/// the round structure is what produces the paper's
/// `runtime ≈ N·(c + s/W)` diminishing-returns curve.
#[derive(Debug, Clone)]
pub struct ClientJob {
    pub client: usize,
    pub config: QuClassiConfig,
    pub n_circuits: usize,
    pub bank_size: usize,
}

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub workers: Vec<SimWorkerSpec>,
    pub env: EnvParams,
    pub calib: Calibration,
    /// Heartbeat period (paper: 5 s).
    pub heartbeat_period: f64,
    pub tenancy: Tenancy,
    /// Work stealing between worker backlogs (mirrors
    /// `ManagerConfig::steal`, DESIGN.md §14): on FIFO backends, a
    /// worker that completes a circuit with an empty backlog of its own
    /// takes the oldest compatible bound-but-unstarted circuit from the
    /// deepest sibling backlog, moving its qubit reservation. No effect
    /// on processor-sharing backends (`cpu_share`), where every bound
    /// circuit starts immediately. With `steal: false` the FIFO model
    /// reproduces the pre-steal schedule exactly (service times are
    /// drawn at bind time either way, so the RNG stream is identical).
    pub steal: bool,
    /// Shard the pool, mirroring [`crate::coordinator::ShardManager`]
    /// (DESIGN.md §18): workers join shards round-robin by registration
    /// order (the DES analog of live least-populated placement), a
    /// client's circuits bind only to its home shard `client % shards`,
    /// and — with [`SimConfig::steal`] on — an idle FIFO worker whose
    /// own shard has no stealable backlog steals *cross-shard* (the
    /// analog of the broker's idle-only export path; counted in
    /// [`SimResult::cross_shard_steals`]). `0` or `1` is the unsharded
    /// identity: the exact pre-shard code path and schedule.
    pub shards: usize,
    /// Noise-aware placement gate, mirroring
    /// `ManagerConfig::noise_aware_alpha`: `Some(alpha)` restricts both
    /// Algorithm-2 selection *and* backlog stealing to workers within
    /// [`scheduler::noise_cutoff`] — the same shared predicate the live
    /// manager and `Manager::steal_for` consult (PR 10), so the DES
    /// quantifies the same fidelity/latency trade-off. `None` is the
    /// paper's CRU-only rule.
    pub noise_aware_alpha: Option<f64>,
    pub seed: u64,
}

/// Per-client outcome.
#[derive(Debug, Clone)]
pub struct ClientResult {
    pub client: usize,
    pub circuits: usize,
    /// Time the client's last circuit completed.
    pub finish: f64,
    /// Circuits per second over the client's span.
    pub cps: f64,
}

/// Whole-run outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Completion time of the entire workload ("runtime per epoch").
    pub makespan: f64,
    pub total_circuits: usize,
    /// Aggregate circuits per second.
    pub cps: f64,
    pub per_client: Vec<ClientResult>,
    /// DES events executed (sanity/observability).
    pub events: u64,
    /// Circuits stolen across shard boundaries (0 when `shards <= 1`;
    /// mirrors `ShardManager::cross_steals`).
    pub cross_shard_steals: u64,
}

#[derive(Debug, Clone)]
struct SimJob {
    client: usize,
    config: QuClassiConfig,
    seq: u64,
}

struct WorkerModel {
    spec: SimWorkerSpec,
    /// Circuits assigned and not yet complete (executing or backlogged).
    concurrent: usize,
    /// FIFO backends: a circuit is currently in service.
    busy: bool,
    /// FIFO backends: circuits bound to this worker awaiting the
    /// backend, with their bind-time service draws — the stealable
    /// queue (the analog of the live manager's outbox).
    backlog: VecDeque<(SimJob, f64)>,
}

struct ClientState {
    config: QuClassiConfig,
    /// Circuits not yet submitted.
    unsubmitted: usize,
    /// Circuits submitted in the current round, still running.
    in_flight: usize,
    bank_size: usize,
    finish: f64,
}

struct SimState {
    registry: Registry,
    worker_ids: Vec<WorkerId>,
    models: BTreeMap<WorkerId, WorkerModel>,
    /// Per-client pending sub-queues (tenant-fair admission parity with
    /// the live manager's `AdmissionQueue`, DESIGN.md §13).
    pending: BTreeMap<usize, VecDeque<SimJob>>,
    /// Clients with a non-empty sub-queue, in round-robin service order.
    rr: VecDeque<usize>,
    env: EnvParams,
    calib: Calibration,
    tenancy: Tenancy,
    /// FIFO-backlog work stealing on/off (see [`SimConfig::steal`]).
    steal: bool,
    /// Shard count (normalized: `>= 1`; see [`SimConfig::shards`]).
    shards: usize,
    /// Worker → shard assignment (round-robin by registration order).
    shard_of: BTreeMap<WorkerId, usize>,
    /// Cross-shard steals taken so far.
    cross_steals: u64,
    /// Noise-aware gate (see [`SimConfig::noise_aware_alpha`]).
    noise_alpha: Option<f64>,
    rng: Rng,
    next_job: u64,
    clients: Vec<ClientState>,
    total_done: usize,
    total: usize,
}

impl SimState {
    /// Lowest client index that still has work (the "occupant" in
    /// single-tenant mode).
    fn active_client(&self) -> Option<usize> {
        self.clients
            .iter()
            .position(|c| c.unsubmitted > 0 || c.in_flight > 0)
    }

    /// Admit one circuit to its client's sub-queue.
    fn enqueue(&mut self, job: SimJob) {
        let client = job.client;
        let was_empty = self.pending.get(&client).map_or(true, |q| q.is_empty());
        self.pending.entry(client).or_default().push_back(job);
        if was_empty {
            self.rr.push_back(client);
        }
    }

    /// Pop `client`'s head-of-line circuit and advance the round-robin
    /// cursor (served tenants rotate to the back; drained tenants leave
    /// the service order).
    fn pop_head(&mut self, client: usize) -> Option<SimJob> {
        let q = self.pending.get_mut(&client)?;
        let job = q.pop_front();
        if q.is_empty() {
            self.pending.remove(&client);
            self.rr.retain(|&c| c != client);
        } else {
            self.rr.retain(|&c| c != client);
            self.rr.push_back(client);
        }
        job
    }

    /// Algorithm-2 selection, restricted by tenancy and (when sharded)
    /// the job's home shard.
    fn select(&self, job: &SimJob) -> Option<WorkerId> {
        let demand = job.config.qubit_demand();
        if self.tenancy == Tenancy::SingleTenant && self.active_client() != Some(job.client) {
            // Only the current occupant may execute circuits.
            return None;
        }
        if self.shards <= 1 {
            // Unsharded: the exact live scheduler entry points,
            // including the manager's noise-aware dispatch switch.
            return match self.noise_alpha {
                Some(alpha) => scheduler::select_noise_aware(&self.registry, demand, alpha),
                None => scheduler::select(&self.registry, demand),
            };
        }
        self.select_in_shard(demand, job.client % self.shards)
    }

    /// [`scheduler::select`] restricted to one shard's workers: the same
    /// two-pass rule (strict `AR > D`, then relaxed `AR >= D`) with the
    /// same deterministic tie-break `(CRU asc, AR desc, id asc)` — only
    /// the candidate set shrinks, exactly as each live shard's manager
    /// sees only its own registry.
    fn select_in_shard(&self, demand: usize, shard: usize) -> Option<WorkerId> {
        // Noise gate via the shared cutoff (computed over the whole
        // registry — in the single-registry DES that is the pool the
        // cutoff is defined on; each live shard computes it over its own
        // registry, which *is* its whole pool).
        let cutoff = self.noise_alpha.and_then(|a| scheduler::noise_cutoff(&self.registry, a));
        let pick = |strict: bool| {
            let mut best: Option<(f64, std::cmp::Reverse<usize>, WorkerId)> = None;
            for w in self.registry.workers() {
                if self.shard_of.get(&w.id) != Some(&shard) {
                    continue;
                }
                if let Some(c) = cutoff {
                    if w.noise > c {
                        continue;
                    }
                }
                let fits =
                    if strict { w.available() > demand } else { w.available() >= demand };
                if fits {
                    let key = (w.cru, std::cmp::Reverse(w.available()), w.id);
                    if best.is_none()
                        || (key.0, key.1, key.2)
                            < (best.unwrap().0, best.unwrap().1, best.unwrap().2)
                    {
                        best = Some(key);
                    }
                }
            }
            best.map(|(_, _, id)| id)
        };
        pick(true).or_else(|| pick(false))
    }

    /// Service time for one circuit starting now on `worker`.
    fn service_time(&mut self, worker: WorkerId, config: &QuClassiConfig) -> f64 {
        let model = &self.models[&worker];
        let mut t = self.calib.exec_time(config) / model.spec.speed;
        if self.env.jitter_sigma > 0.0 {
            // lognormal with unit median
            t *= self.rng.lognormal(0.0, self.env.jitter_sigma);
        }
        if self.env.queue_delay_mean > 0.0 {
            t += self.rng.exponential(1.0 / self.env.queue_delay_mean);
        }
        if self.env.cpu_share {
            // processor sharing approximation: pay for the circuits
            // already on the core (including this one)
            t *= (model.concurrent + 1) as f64;
        }
        t
    }

    fn cru(&self, worker: WorkerId) -> f64 {
        let model = &self.models[&worker];
        (model.concurrent as f64 * self.env.cru_per_circuit).clamp(0.0, 1.0)
    }
}

/// Try to place pending circuits; schedules completion events.
///
/// Tenant-fair parity with the live manager: each pass probes every
/// client's head-of-line circuit in round-robin service order (a blocked
/// head skips to the next tenant instead of stalling it), and passes
/// repeat until no circuit can be placed — work-conserving, like the old
/// global-FIFO scan, but with the manager's admission order.
fn try_assign(des: &mut Des<SimState>, st: &mut SimState) {
    loop {
        let mut assigned = false;
        let order: Vec<usize> = st.rr.iter().copied().collect();
        for client in order {
            let Some(job) = st.pending.get(&client).and_then(|q| q.front()).cloned() else {
                continue;
            };
            let Some(worker) = st.select(&job) else {
                continue; // this tenant's head is blocked; try the next
            };
            st.pop_head(client);
            let demand = job.config.qubit_demand();
            st.registry
                .reserve(worker, job.seq, demand)
                .expect("selection guaranteed capacity");
            // The service time is drawn at *bind* time (whatever backend
            // ends up running the circuit), so the RNG stream — and with
            // steal off, the whole schedule — is independent of steals.
            let s = st.service_time(worker, &job.config);
            let model = st.models.get_mut(&worker).unwrap();
            model.concurrent += 1;
            if st.env.fifo {
                if model.busy {
                    // Sequential backend already serving: the circuit
                    // waits in the worker's backlog (stealable).
                    model.backlog.push_back((job, s));
                } else {
                    model.busy = true;
                    des.schedule(s, move |des, st| {
                        complete(des, st, worker, job);
                    });
                }
            } else {
                des.schedule(s, move |des, st| {
                    complete(des, st, worker, job);
                });
            }
            assigned = true;
        }
        if !assigned {
            break;
        }
    }
}

/// Start the next circuit on an idle FIFO backend.
fn start_fifo(des: &mut Des<SimState>, st: &mut SimState, worker: WorkerId, job: SimJob, s: f64) {
    let model = st.models.get_mut(&worker).unwrap();
    debug_assert!(!model.busy, "FIFO backend double-started");
    model.busy = true;
    des.schedule(s, move |des, st| {
        complete(des, st, worker, job);
    });
}

/// Steal the oldest compatible bound-but-unstarted circuit from the
/// sibling with the deepest backlog (ties broken by lowest worker id),
/// moving its qubit reservation to the thief and rescaling the
/// bind-time service draw by the speed ratio — the DES mirror of
/// `Manager::steal_for` (DESIGN.md §14), so tenancy experiments see the
/// same policy the live manager runs.
///
/// Sharded pools steal in two phases, mirroring `ShardManager`: the
/// thief's own shard is scanned first, and only when *nothing* in the
/// home shard fits does the scan widen to foreign shards (the broker's
/// idle-only export rule, DESIGN.md §18). Cross-shard takes bump
/// `SimState::cross_steals`.
fn steal_from_sibling(st: &mut SimState, thief: WorkerId) -> Option<(SimJob, f64)> {
    let thief_avail = st.registry.get(thief)?.available();
    if thief_avail == 0 {
        return None;
    }
    // PR 10: noise-aware placement composes with stealing — a thief the
    // assigner would refuse under `noise_aware_alpha` cannot pull work
    // through the steal path either (the exact predicate
    // `Manager::steal_for` checks, via the shared cutoff).
    if let Some(alpha) = st.noise_alpha {
        let thief_noise = st.registry.get(thief)?.noise;
        match scheduler::noise_cutoff(&st.registry, alpha) {
            Some(cutoff) if thief_noise <= cutoff => {}
            _ => return None,
        }
    }
    let occupant = st.active_client();
    let single = st.tenancy == Tenancy::SingleTenant;
    let thief_shard = st.shard_of.get(&thief).copied().unwrap_or(0);
    // Victims deepest-backlog-first (ties: lowest id), falling through
    // to shallower siblings when nothing in a deeper backlog fits —
    // the same scan order as `Manager::steal_for`. Home-shard victims
    // form the whole first phase; foreign shards are phase two.
    let mut victims: Vec<(usize, WorkerId, bool)> = st
        .models
        .iter()
        .filter(|(id, model)| **id != thief && !model.backlog.is_empty())
        .map(|(id, model)| {
            let foreign = st.shard_of.get(id).copied().unwrap_or(0) != thief_shard;
            (model.backlog.len(), *id, foreign)
        })
        .collect();
    victims.sort_by(|a, b| a.2.cmp(&b.2).then(b.0.cmp(&a.0)).then(a.1.cmp(&b.1)));
    for (_, victim, foreign) in victims {
        let Some(idx) = st.models[&victim].backlog.iter().position(|(job, _)| {
            job.config.qubit_demand() <= thief_avail
                && (!single || occupant == Some(job.client))
        }) else {
            continue;
        };
        let (job, s) =
            st.models.get_mut(&victim).unwrap().backlog.remove(idx).expect("index valid");
        let demand = job.config.qubit_demand();
        st.registry.release(victim, job.seq);
        st.registry.reserve(thief, job.seq, demand).expect("steal capacity checked");
        st.models.get_mut(&victim).unwrap().concurrent -= 1;
        st.models.get_mut(&thief).unwrap().concurrent += 1;
        if foreign {
            st.cross_steals += 1;
        }
        let victim_speed = st.models[&victim].spec.speed;
        let thief_speed = st.models[&thief].spec.speed;
        return Some((job, s * victim_speed / thief_speed));
    }
    None
}

fn complete(des: &mut Des<SimState>, st: &mut SimState, worker: WorkerId, job: SimJob) {
    st.registry.release(worker, job.seq);
    {
        let model = st.models.get_mut(&worker).unwrap();
        model.concurrent -= 1;
        if st.env.fifo {
            model.busy = false;
        }
    }
    if st.env.fifo {
        // Keep the freed backend busy: own backlog first; a worker left
        // idle with an empty backlog steals from a backed-up sibling.
        if let Some((next, s)) = st.models.get_mut(&worker).unwrap().backlog.pop_front() {
            start_fifo(des, st, worker, next, s);
        } else if st.steal {
            if let Some((next, s)) = steal_from_sibling(st, worker) {
                start_fifo(des, st, worker, next, s);
            }
        }
    }
    st.total_done += 1;
    let client = job.client;
    let c = &mut st.clients[client];
    c.in_flight -= 1;
    if c.in_flight == 0 {
        if c.unsubmitted == 0 {
            c.finish = des.now();
        } else {
            // round finished: serial analysis + next-bank build, then submit
            start_round(des, st, client);
        }
    }
    try_assign(des, st);
}

/// Begin a client's next round: serial classical work for the whole bank
/// (build + analysis), then the bank's circuits join the pending queue.
fn start_round(des: &mut Des<SimState>, st: &mut SimState, client: usize) {
    let c = &mut st.clients[client];
    let bank = c.bank_size.min(c.unsubmitted);
    debug_assert!(bank > 0);
    c.unsubmitted -= bank;
    c.in_flight = bank;
    let config = c.config;
    let serial = bank as f64 * st.env.client_overhead;
    des.schedule(serial, move |des, st: &mut SimState| {
        for _ in 0..bank {
            let seq = st.next_job;
            st.next_job += 1;
            st.enqueue(SimJob { client, config, seq });
        }
        try_assign(des, st);
    });
}

fn heartbeat(des: &mut Des<SimState>, st: &mut SimState, period: f64) {
    // Paper-faithful: recompute OR from the active set, refresh CRU.
    let ids: Vec<WorkerId> = st.worker_ids.clone();
    let now = des.now();
    for id in ids {
        let active: Vec<(u64, usize)> = st
            .registry
            .get(id)
            .map(|w| w.active.iter().map(|(k, v)| (*k, *v)).collect())
            .unwrap_or_default();
        let cru = st.cru(id);
        let _ = st.registry.heartbeat_recompute(id, &active, cru, now);
    }
    if st.total_done < st.total {
        des.schedule(period, move |des, st| heartbeat(des, st, period));
    }
}

/// Run one workload through the simulated cluster.
pub fn simulate(cfg: &SimConfig, jobs: &[ClientJob]) -> SimResult {
    let shards = cfg.shards.max(1);
    // Upfront placement validation: an unplaceable job would leave the
    // heartbeat loop live forever; fail loudly instead. Sharded pools
    // must place every job on its *home* shard: the DES steals at the
    // backlog (bound-circuit) level, so a circuit that can never bind at
    // home can never be exported either (the live broker exports from
    // the admission queue and has no such restriction — DESIGN.md §18).
    for j in jobs {
        let d = j.config.qubit_demand();
        let placeable = cfg
            .workers
            .iter()
            .enumerate()
            .any(|(i, w)| (shards == 1 || i % shards == j.client % shards) && w.max_qubits >= d);
        assert!(
            placeable,
            "client {} job needs {d} qubits; no eligible worker on its shard under {:?}",
            j.client, cfg.tenancy
        );
    }
    let n_clients = jobs.iter().map(|j| j.client + 1).max().unwrap_or(0);
    assert_eq!(n_clients, jobs.len(), "client ids must be 0..n dense, one job each");
    let mut registry = Registry::new(cfg.heartbeat_period);
    let mut worker_ids = Vec::new();
    let mut models = BTreeMap::new();
    let mut shard_of = BTreeMap::new();
    for (i, spec) in cfg.workers.iter().enumerate() {
        let id = registry.register_with_noise(spec.max_qubits, 0.0, spec.noise, 0.0);
        worker_ids.push(id);
        shard_of.insert(id, i % shards);
        models.insert(
            id,
            WorkerModel { spec: *spec, concurrent: 0, busy: false, backlog: VecDeque::new() },
        );
    }
    let mut clients: Vec<ClientState> = jobs
        .iter()
        .map(|j| ClientState {
            config: j.config,
            unsubmitted: j.n_circuits,
            in_flight: 0,
            bank_size: j.bank_size.max(1),
            finish: 0.0,
        })
        .collect();
    clients.sort_by_key(|_| 0u8); // stable; jobs are dense by construction
    let total = jobs.iter().map(|j| j.n_circuits).sum();

    let mut st = SimState {
        registry,
        worker_ids,
        models,
        pending: BTreeMap::new(),
        rr: VecDeque::new(),
        env: cfg.env,
        calib: cfg.calib.clone(),
        tenancy: cfg.tenancy.clone(),
        steal: cfg.steal,
        shards,
        shard_of,
        cross_steals: 0,
        noise_alpha: cfg.noise_aware_alpha,
        rng: Rng::new(cfg.seed),
        next_job: 0,
        clients,
        total_done: 0,
        total,
    };

    let mut des: Des<SimState> = Des::new();

    // Kick off every client's first round (clients run concurrently).
    for j in jobs {
        let client = j.client;
        des.schedule(0.0, move |des, st: &mut SimState| start_round(des, st, client));
    }
    // Heartbeats.
    let period = cfg.heartbeat_period;
    des.schedule(period, move |des, st| heartbeat(des, st, period));

    des.run(&mut st);
    assert_eq!(st.total_done, total, "simulation lost circuits");
    // Makespan = when the last circuit completed (the trailing heartbeat
    // event may fire later; it must not inflate the epoch runtime).
    let makespan = st.clients.iter().map(|c| c.finish).fold(0.0f64, f64::max);

    let per_client = jobs
        .iter()
        .map(|j| {
            let finish = st.clients[j.client].finish;
            ClientResult {
                client: j.client,
                circuits: j.n_circuits,
                finish,
                cps: j.n_circuits as f64 / finish.max(1e-9),
            }
        })
        .collect();
    SimResult {
        makespan,
        total_circuits: total,
        cps: total as f64 / makespan.max(1e-9),
        per_client,
        events: des.executed(),
        cross_shard_steals: st.cross_steals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_config(workers: &[usize], tenancy: Tenancy, env: EnvParams) -> SimConfig {
        SimConfig {
            workers: workers
                .iter()
                .map(|&q| SimWorkerSpec { max_qubits: q, speed: 1.0, noise: 0.0 })
                .collect(),
            env,
            calib: Calibration::qiskit_like(),
            heartbeat_period: 5.0,
            tenancy,
            steal: true,
            shards: 1,
            noise_aware_alpha: None,
            seed: 42,
        }
    }

    fn one_client(config: QuClassiConfig, n: usize) -> Vec<ClientJob> {
        vec![ClientJob { client: 0, config, n_circuits: n, bank_size: 32 }]
    }

    #[test]
    fn more_workers_reduce_runtime() {
        let cfg5l3 = QuClassiConfig::new(5, 3).unwrap();
        let jobs = one_client(cfg5l3, 400);
        let t1 = simulate(
            &base_config(&[5], Tenancy::MultiTenant, EnvParams::gcp_controlled()),
            &jobs,
        );
        let t2 = simulate(
            &base_config(&[5, 5], Tenancy::MultiTenant, EnvParams::gcp_controlled()),
            &jobs,
        );
        let t4 = simulate(
            &base_config(&[5, 5, 5, 5], Tenancy::MultiTenant, EnvParams::gcp_controlled()),
            &jobs,
        );
        assert!(t2.makespan < t1.makespan, "{} !< {}", t2.makespan, t1.makespan);
        assert!(t4.makespan < t2.makespan);
        // and circuits/sec increases
        assert!(t4.cps > t2.cps && t2.cps > t1.cps);
        // diminishing returns: 4 workers is NOT 4x faster (client overhead
        // serializes) — the paper's central observation
        assert!(t4.makespan > t1.makespan / 4.0);
    }

    #[test]
    fn deeper_circuits_take_longer() {
        let jobs1 = one_client(QuClassiConfig::new(5, 1).unwrap(), 200);
        let jobs3 = one_client(QuClassiConfig::new(5, 3).unwrap(), 200);
        let cfg = base_config(&[5, 5], Tenancy::MultiTenant, EnvParams::gcp_controlled());
        let r1 = simulate(&cfg, &jobs1);
        let r3 = simulate(&cfg, &jobs3);
        assert!(r3.makespan > r1.makespan);
    }

    #[test]
    fn multi_tenant_beats_single_tenant_for_small_jobs() {
        // Fig 6's effect: the 5Q/1L client gains hugely from sharing the
        // pool instead of being pinned to the small worker.
        // queue order: big jobs first, the small 5Q/1L job last (client 3)
        let jobs = vec![
            ClientJob { client: 0, config: QuClassiConfig::new(7, 2).unwrap(), n_circuits: 150, bank_size: 32 },
            ClientJob { client: 1, config: QuClassiConfig::new(5, 2).unwrap(), n_circuits: 150, bank_size: 32 },
            ClientJob { client: 2, config: QuClassiConfig::new(7, 1).unwrap(), n_circuits: 150, bank_size: 32 },
            ClientJob { client: 3, config: QuClassiConfig::new(5, 1).unwrap(), n_circuits: 150, bank_size: 32 },
        ];
        let workers = [5usize, 10, 15, 20];
        let single = simulate(
            &base_config(&workers, Tenancy::SingleTenant, EnvParams::gcp_controlled()),
            &jobs,
        );
        let multi = simulate(
            &base_config(&workers, Tenancy::MultiTenant, EnvParams::gcp_controlled()),
            &jobs,
        );
        let s3 = single.per_client[3].finish;
        let m3 = multi.per_client[3].finish;
        assert!(m3 < s3, "5Q/1L multi {m3} !< single {s3}");
        // throughput of the small job improves substantially (paper: 3.9x)
        assert!(multi.per_client[3].cps > 1.5 * single.per_client[3].cps);
    }

    #[test]
    fn single_tenant_serializes_clients() {
        // Two identical clients: in single-tenant mode client 1 waits for
        // client 0, so its finish is ~2x client 0's; in multi-tenant they
        // overlap and finish together.
        let cfg5 = QuClassiConfig::new(5, 1).unwrap();
        let jobs = vec![
            ClientJob { client: 0, config: cfg5, n_circuits: 64, bank_size: 16 },
            ClientJob { client: 1, config: cfg5, n_circuits: 64, bank_size: 16 },
        ];
        let single = simulate(
            &base_config(&[5, 5], Tenancy::SingleTenant, EnvParams::gcp_controlled()),
            &jobs,
        );
        let multi = simulate(
            &base_config(&[5, 5], Tenancy::MultiTenant, EnvParams::gcp_controlled()),
            &jobs,
        );
        assert!(
            single.per_client[1].finish > 1.7 * single.per_client[0].finish,
            "single-tenant client 1 did not queue: {} vs {}",
            single.per_client[1].finish,
            single.per_client[0].finish
        );
        let ratio = multi.per_client[1].finish / single.per_client[1].finish;
        assert!(ratio < 0.85, "multi-tenant gave no gain: ratio {ratio}");
    }

    #[test]
    fn unplaceable_workload_detected() {
        let jobs = vec![ClientJob {
            client: 0,
            config: QuClassiConfig::new(7, 1).unwrap(),
            n_circuits: 3,
            bank_size: 8,
        }];
        let cfg = base_config(&[5], Tenancy::MultiTenant, EnvParams::gcp_controlled());
        let result = std::panic::catch_unwind(|| simulate(&cfg, &jobs));
        assert!(result.is_err(), "expected unplaceable workload to be detected");
    }

    #[test]
    fn deterministic_per_seed() {
        let jobs = one_client(QuClassiConfig::new(5, 2).unwrap(), 100);
        let cfg = base_config(&[5, 5], Tenancy::MultiTenant, EnvParams::ibmq_uncontrolled());
        let a = simulate(&cfg, &jobs);
        let b = simulate(&cfg, &jobs);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn uncontrolled_jitter_changes_with_seed() {
        let jobs = one_client(QuClassiConfig::new(5, 2).unwrap(), 100);
        let mut cfg = base_config(&[5, 5], Tenancy::MultiTenant, EnvParams::ibmq_uncontrolled());
        let a = simulate(&cfg, &jobs);
        cfg.seed = 43;
        let b = simulate(&cfg, &jobs);
        assert_ne!(a.makespan, b.makespan);
    }

    /// Deterministic FIFO environment (no jitter, no cloud queueing):
    /// isolates the steal policy from stochastic effects.
    fn fifo_env() -> EnvParams {
        EnvParams {
            client_overhead: 0.01,
            jitter_sigma: 0.0,
            queue_delay_mean: 0.0,
            cpu_share: false,
            fifo: true,
            cru_per_circuit: 0.10,
        }
    }

    #[test]
    fn steal_rebalances_skewed_fifo_backlogs() {
        // One 4x-slow + one fast FIFO backend. Between heartbeats the
        // registry's CRU is stale, so binding splits roughly evenly and
        // the slow worker's backlog grows 4x deeper — exactly the
        // binding-time skew the live manager's work stealing targets.
        // With steal on, the fast worker drains the slow backlog and the
        // epoch finishes strictly earlier; with steal off the model
        // reproduces the pre-steal schedule.
        let jobs = one_client(QuClassiConfig::new(5, 1).unwrap(), 200);
        let mk = |steal: bool| SimConfig {
            workers: vec![
                SimWorkerSpec { max_qubits: 64, speed: 0.25, noise: 0.0 },
                SimWorkerSpec { max_qubits: 64, speed: 1.0, noise: 0.0 },
            ],
            env: fifo_env(),
            calib: Calibration::qiskit_like(),
            heartbeat_period: 5.0,
            tenancy: Tenancy::MultiTenant,
            steal,
            shards: 1,
            noise_aware_alpha: None,
            seed: 9,
        };
        let on = simulate(&mk(true), &jobs);
        let off = simulate(&mk(false), &jobs);
        assert!(
            on.makespan < off.makespan,
            "steal on {} !< steal off {}",
            on.makespan,
            off.makespan
        );
        // conservation holds either way (simulate asserts internally),
        // and the policy is deterministic per seed
        let on2 = simulate(&mk(true), &jobs);
        assert_eq!(on.makespan, on2.makespan);
    }

    #[test]
    fn big_worker_hosts_concurrent_small_circuits() {
        // One 20-qubit worker, controlled env: four 5-qubit circuits run
        // concurrently (processor-shared), so makespan is far less than
        // 4x the serial case for a burst of 4.
        let jobs = one_client(QuClassiConfig::new(5, 1).unwrap(), 40);
        let small = simulate(
            &base_config(&[5], Tenancy::MultiTenant, EnvParams::gcp_controlled()),
            &jobs,
        );
        let big = simulate(
            &base_config(&[20], Tenancy::MultiTenant, EnvParams::gcp_controlled()),
            &jobs,
        );
        // processor sharing means the 20q worker is not 4x faster, but it
        // must not be slower than the 5q worker
        assert!(big.makespan <= small.makespan * 1.05);
    }

    #[test]
    fn zero_shards_is_unsharded_identity() {
        // `shards: 0` normalizes to 1 and takes the exact pre-shard code
        // path — bit-identical schedule.
        let jobs = one_client(QuClassiConfig::new(5, 2).unwrap(), 100);
        let mut cfg = base_config(&[5, 5], Tenancy::MultiTenant, EnvParams::ibmq_uncontrolled());
        let a = simulate(&cfg, &jobs);
        cfg.shards = 0;
        let b = simulate(&cfg, &jobs);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.events, b.events);
        assert_eq!(a.cross_shard_steals, 0);
        assert_eq!(b.cross_shard_steals, 0);
    }

    #[test]
    fn sharded_routing_pins_clients_to_home_shards() {
        // Two shards, one FIFO worker each; shard 0's worker is 4x
        // slower. With steal off, identical clients are fully isolated:
        // client 0 (home shard 0) must finish far later than client 1 —
        // proof the router never spills onto the foreign shard.
        let cfg5 = QuClassiConfig::new(5, 1).unwrap();
        let jobs = vec![
            ClientJob { client: 0, config: cfg5, n_circuits: 60, bank_size: 20 },
            ClientJob { client: 1, config: cfg5, n_circuits: 60, bank_size: 20 },
        ];
        let cfg = SimConfig {
            workers: vec![
                SimWorkerSpec { max_qubits: 64, speed: 0.25, noise: 0.0 },
                SimWorkerSpec { max_qubits: 64, speed: 1.0, noise: 0.0 },
            ],
            env: fifo_env(),
            calib: Calibration::qiskit_like(),
            heartbeat_period: 5.0,
            tenancy: Tenancy::MultiTenant,
            steal: false,
            shards: 2,
            noise_aware_alpha: None,
            seed: 7,
        };
        let r = simulate(&cfg, &jobs);
        assert_eq!(r.cross_shard_steals, 0);
        assert!(
            r.per_client[0].finish > 2.0 * r.per_client[1].finish,
            "shard isolation broken: {} vs {}",
            r.per_client[0].finish,
            r.per_client[1].finish
        );
        let r2 = simulate(&cfg, &jobs);
        assert_eq!(r.makespan, r2.makespan, "sharded schedule not deterministic");
    }

    #[test]
    fn cross_shard_steal_drains_overloaded_shard() {
        // Shard 0's client submits a huge epoch; shard 1's client a tiny
        // one. With steal on, shard 1's worker drains its own circuits,
        // finds its home shard dry, and pulls from shard 0's backlog —
        // the broker's idle-only export rule — strictly improving the
        // epoch over the isolated schedule.
        let cfg5 = QuClassiConfig::new(5, 1).unwrap();
        let jobs = vec![
            ClientJob { client: 0, config: cfg5, n_circuits: 200, bank_size: 64 },
            ClientJob { client: 1, config: cfg5, n_circuits: 8, bank_size: 8 },
        ];
        let mk = |steal: bool| SimConfig {
            workers: vec![
                SimWorkerSpec { max_qubits: 64, speed: 1.0, noise: 0.0 },
                SimWorkerSpec { max_qubits: 64, speed: 1.0, noise: 0.0 },
            ],
            env: fifo_env(),
            calib: Calibration::qiskit_like(),
            heartbeat_period: 5.0,
            tenancy: Tenancy::MultiTenant,
            steal,
            shards: 2,
            noise_aware_alpha: None,
            seed: 11,
        };
        let on = simulate(&mk(true), &jobs);
        let off = simulate(&mk(false), &jobs);
        assert!(on.cross_shard_steals > 0, "no cross-shard steals recorded");
        assert_eq!(off.cross_shard_steals, 0);
        assert!(on.makespan < off.makespan, "steal on {} !< off {}", on.makespan, off.makespan);
    }

    #[test]
    fn sharded_unplaceable_at_home_detected() {
        // Shard 1 (client 1's home) only has the 5-qubit worker; a
        // 7-qubit job there must fail fast even though shard 0 could
        // host it — the DES steals bound circuits only, so the job
        // could never bind (see the validation note in `simulate`).
        let jobs = vec![
            ClientJob {
                client: 0,
                config: QuClassiConfig::new(5, 1).unwrap(),
                n_circuits: 2,
                bank_size: 4,
            },
            ClientJob {
                client: 1,
                config: QuClassiConfig::new(7, 1).unwrap(),
                n_circuits: 2,
                bank_size: 4,
            },
        ];
        let mut cfg = base_config(&[20, 5], Tenancy::MultiTenant, EnvParams::gcp_controlled());
        cfg.shards = 2;
        let result = std::panic::catch_unwind(|| simulate(&cfg, &jobs));
        assert!(result.is_err(), "expected home-shard placement validation to fire");
    }

    #[test]
    fn noise_aware_alpha_gates_placement_and_stealing() {
        // Mirror of the live manager's PR 10 composition: `Some(alpha)`
        // threads `scheduler::noise_cutoff` through Algorithm-2 selection
        // *and* the steal path. Two identical-speed 20q FIFO workers, one
        // ideal and one noisy. With alpha = 1.0 the whole epoch is
        // confined to the clean backend — the noisy worker receives no
        // work by placement, and the steal gate keeps it from pulling any
        // through the back door — so the epoch takes ~2x the CRU-only
        // schedule. alpha = 0.0 admits the full pool and reproduces the
        // paper rule's schedule exactly (same selections, same event
        // count), proving the gate's pass-through arm is the identity.
        let jobs = one_client(QuClassiConfig::new(5, 1).unwrap(), 64);
        let env = EnvParams {
            client_overhead: 0.0,
            jitter_sigma: 0.0,
            queue_delay_mean: 0.0,
            cpu_share: false,
            fifo: true,
            cru_per_circuit: 0.45,
        };
        let mk = |alpha: Option<f64>| SimConfig {
            workers: vec![
                SimWorkerSpec { max_qubits: 20, speed: 1.0, noise: 0.0 },
                SimWorkerSpec { max_qubits: 20, speed: 1.0, noise: 0.05 },
            ],
            env,
            calib: Calibration::qiskit_like(),
            heartbeat_period: 5.0,
            tenancy: Tenancy::MultiTenant,
            steal: true,
            shards: 1,
            noise_aware_alpha: alpha,
            seed: 13,
        };
        let paper = simulate(&mk(None), &jobs);
        let gated = simulate(&mk(Some(1.0)), &jobs);
        let zero = simulate(&mk(Some(0.0)), &jobs);
        assert!(
            gated.makespan >= 1.9 * paper.makespan,
            "noise gate did not confine the epoch: gated {} vs paper {}",
            gated.makespan,
            paper.makespan
        );
        assert!(
            (zero.makespan - paper.makespan).abs() < 1e-9,
            "alpha = 0 drifted off the paper rule: {} vs {}",
            zero.makespan,
            paper.makespan
        );
        assert_eq!(zero.events, paper.events, "alpha = 0 changed the event schedule");
    }
}
