//! Per-circuit service-time calibration.
//!
//! The DES needs "how long does one (q, l) circuit take on a quantum
//! worker". Two sources:
//!
//! 1. [`Calibration::qiskit_like`] — defaults with the magnitudes the
//!    paper's per-circuit times imply (runtime / circuit count from
//!    Figures 3-5: tens of milliseconds, growing with depth and width).
//! 2. [`Calibration::from_measured`] — real per-circuit timings of *this*
//!    machine's PJRT executor (the figure benches measure and inject
//!    them, scaled to backend magnitude).

use std::collections::BTreeMap;

use crate::circuit::QuClassiConfig;

/// Seconds of quantum-worker execution per circuit, per configuration.
#[derive(Debug, Clone)]
pub struct Calibration {
    exec_secs: BTreeMap<(usize, usize), f64>,
}

impl Calibration {
    /// Paper-magnitude defaults.
    ///
    /// Derived from the paper's own 1-worker numbers (runtime / #circuits):
    /// 5Q ≈ 66/162/174 ms and 7Q ≈ 81/141/226 ms for 1/2/3 layers —
    /// roughly "deeper and wider is slower". We use a simple linear model
    /// in the layer count with a width factor, which preserves those
    /// orderings.
    pub fn qiskit_like() -> Calibration {
        let mut exec_secs = BTreeMap::new();
        for q in [5usize, 7] {
            for l in [1usize, 2, 3] {
                let width_factor = if q == 5 { 1.0 } else { 1.5 };
                exec_secs.insert((q, l), 0.020 * l as f64 * width_factor);
            }
        }
        Calibration { exec_secs }
    }

    /// Build from measured per-circuit seconds.
    pub fn from_measured(measured: &[(QuClassiConfig, f64)]) -> Calibration {
        Calibration {
            exec_secs: measured
                .iter()
                .map(|(c, s)| ((c.qubits, c.layers), *s))
                .collect(),
        }
    }

    /// Uniformly scale all service times (e.g. map this machine's PJRT
    /// microseconds to cloud-backend milliseconds).
    pub fn scaled(&self, factor: f64) -> Calibration {
        Calibration {
            exec_secs: self.exec_secs.iter().map(|(k, v)| (*k, v * factor)).collect(),
        }
    }

    /// Execution seconds for one circuit of this configuration.
    pub fn exec_time(&self, config: &QuClassiConfig) -> f64 {
        if let Some(&s) = self.exec_secs.get(&(config.qubits, config.layers)) {
            return s;
        }
        // Fallback: interpolate from the closest known layer count.
        self.exec_secs
            .iter()
            .min_by_key(|((q, l), _)| {
                (q.abs_diff(config.qubits)) * 10 + l.abs_diff(config.layers)
            })
            .map(|(_, &s)| s)
            .unwrap_or(0.02)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_grow_with_depth_and_width() {
        let c = Calibration::qiskit_like();
        let t = |q, l| c.exec_time(&QuClassiConfig::new(q, l).unwrap());
        assert!(t(5, 1) < t(5, 2));
        assert!(t(5, 2) < t(5, 3));
        assert!(t(5, 1) < t(7, 1));
        assert!(t(7, 2) < t(7, 3));
    }

    #[test]
    fn measured_overrides() {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let c = Calibration::from_measured(&[(cfg, 0.123)]);
        assert!((c.exec_time(&cfg) - 0.123).abs() < 1e-12);
    }

    #[test]
    fn scaling() {
        let c = Calibration::qiskit_like().scaled(2.0);
        let base = Calibration::qiskit_like();
        let cfg = QuClassiConfig::new(7, 3).unwrap();
        assert!((c.exec_time(&cfg) - 2.0 * base.exec_time(&cfg)).abs() < 1e-12);
    }

    #[test]
    fn fallback_interpolates() {
        let cfg51 = QuClassiConfig::new(5, 1).unwrap();
        let c = Calibration::from_measured(&[(cfg51, 0.05)]);
        // unknown config falls back to the nearest known one
        let cfg91 = QuClassiConfig::new(9, 1).unwrap();
        assert!((c.exec_time(&cfg91) - 0.05).abs() < 1e-12);
    }
}
