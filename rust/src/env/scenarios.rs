//! Ready-made workloads for every figure in the paper's evaluation.
//!
//! Circuit counts are the paper's own: 5Q epochs run 1440/2880/4320
//! circuits for 1/2/3 layers; 7Q epochs run 2016/4032/6048 (§IV-C1).

use crate::circuit::QuClassiConfig;
use crate::env::calib::Calibration;
use crate::env::sim::{ClientJob, EnvParams, SimConfig, SimWorkerSpec, Tenancy};

/// Circuits per client round: one sample's parameter-shift banks across
/// the paper's 4 conv filters (2P shifted circuits per filter).
pub fn round_bank_size(config: &QuClassiConfig) -> usize {
    2 * config.n_params() * 4
}

/// The paper's per-epoch circuit counts.
pub fn epoch_circuits(qubits: usize, layers: usize) -> usize {
    match (qubits, layers) {
        (5, l) => 1440 * l,
        (7, l) => 2016 * l,
        _ => 1440 * layers,
    }
}

/// A row of a runtime/throughput figure.
#[derive(Debug, Clone)]
pub struct FigureRow {
    pub layers: usize,
    pub workers: usize,
    pub circuits: usize,
    pub runtime: f64,
    pub cps: f64,
}

/// Figures 3 & 4: IBM-Q uncontrolled environment, one client, layer and
/// worker sweeps (qubits = 5 for Fig 3, 7 for Fig 4).
pub fn ibmq_figure(qubits: usize, calib: &Calibration, seed: u64) -> Vec<FigureRow> {
    let mut rows = Vec::new();
    for layers in [1usize, 2, 3] {
        let config = QuClassiConfig::new(qubits, layers).expect("valid config");
        let n = epoch_circuits(qubits, layers);
        for workers in [1usize, 2, 4] {
            let sim = SimConfig {
                // "unrestricted quantum workers, without maximum qubit
                // constraints" — give each backend ample qubits but FIFO
                // service (cpu_share = false).
                workers: vec![SimWorkerSpec { max_qubits: 64, speed: 1.0, noise: 0.0 }; workers],
                env: EnvParams::ibmq_uncontrolled(),
                calib: calib.clone(),
                heartbeat_period: 5.0,
                tenancy: Tenancy::MultiTenant,
                // paper-faithful: the published co-Manager has no work
                // stealing and one manager, so figure regeneration keeps
                // both off
                steal: false,
                shards: 1,
                noise_aware_alpha: None,
                seed: seed + layers as u64 * 10 + workers as u64,
            };
            let jobs = vec![ClientJob {
                client: 0,
                config,
                n_circuits: n,
                bank_size: round_bank_size(&config),
            }];
            let r = crate::env::sim::simulate(&sim, &jobs);
            rows.push(FigureRow {
                layers,
                workers,
                circuits: n,
                runtime: r.makespan,
                cps: r.cps,
            });
        }
    }
    rows
}

/// Figure 5: controlled (GCP) environment, one client, 5-qubit workers.
pub fn gcp_one_client_figure(qubits: usize, calib: &Calibration, seed: u64) -> Vec<FigureRow> {
    let mut rows = Vec::new();
    for layers in [1usize, 2, 3] {
        let config = QuClassiConfig::new(qubits, layers).expect("valid config");
        let n = epoch_circuits(qubits, layers);
        for workers in [1usize, 2, 4] {
            let sim = SimConfig {
                workers: vec![SimWorkerSpec { max_qubits: qubits, speed: 1.0, noise: 0.0 }; workers],
                env: EnvParams::gcp_controlled(),
                calib: calib.clone(),
                heartbeat_period: 5.0,
                tenancy: Tenancy::MultiTenant,
                // paper-faithful: the published co-Manager has no work
                // stealing and one manager, so figure regeneration keeps
                // both off
                steal: false,
                shards: 1,
                noise_aware_alpha: None,
                seed: seed + layers as u64 * 10 + workers as u64,
            };
            let jobs = vec![ClientJob {
                client: 0,
                config,
                n_circuits: n,
                bank_size: round_bank_size(&config),
            }];
            let r = crate::env::sim::simulate(&sim, &jobs);
            rows.push(FigureRow {
                layers,
                workers,
                circuits: n,
                runtime: r.makespan,
                cps: r.cps,
            });
        }
    }
    rows
}

/// One client line of the multi-tenant comparison.
#[derive(Debug, Clone)]
pub struct TenancyRow {
    pub label: String,
    pub circuits: usize,
    pub single_runtime: f64,
    pub multi_runtime: f64,
    pub single_cps: f64,
    pub multi_cps: f64,
}

impl TenancyRow {
    pub fn runtime_reduction_pct(&self) -> f64 {
        (1.0 - self.multi_runtime / self.single_runtime) * 100.0
    }

    pub fn cps_gain(&self) -> f64 {
        self.multi_cps / self.single_cps
    }
}

/// Figure 6: four concurrent clients (5Q/1L, 5Q/2L, 7Q/1L, 7Q/2L) on
/// four workers with 5/10/15/20 qubits; single- vs multi-tenant.
pub fn multi_tenant_figure(calib: &Calibration, seed: u64) -> Vec<TenancyRow> {
    // Queue order (= client index) puts the larger jobs first: the paper's
    // single-tenant anecdote has the small 5Q/1L job stuck behind the
    // queue ("one user occupies the entire machine while others wait"),
    // which is exactly where multi-tenancy wins big.
    let specs = [(5usize, 2usize), (7, 1), (7, 2), (5, 1)];
    let jobs: Vec<ClientJob> = specs
        .iter()
        .enumerate()
        .map(|(i, &(q, l))| {
            let config = QuClassiConfig::new(q, l).unwrap();
            ClientJob {
                client: i,
                config,
                // one epoch of the client's own workload, scaled down 4x so
                // the four-job mix finishes in reasonable simulated time,
                // same mix ratio as the paper
                n_circuits: epoch_circuits(q, l) / 4,
                bank_size: round_bank_size(&config),
            }
        })
        .collect();
    let workers: Vec<SimWorkerSpec> = [5usize, 10, 15, 20]
        .iter()
        .map(|&q| SimWorkerSpec { max_qubits: q, speed: 1.0, noise: 0.0 })
        .collect();
    let run = |tenancy: Tenancy, seed: u64| {
        crate::env::sim::simulate(
            &SimConfig {
                workers: workers.clone(),
                env: EnvParams::gcp_controlled(),
                calib: calib.clone(),
                heartbeat_period: 5.0,
                tenancy,
                // paper-faithful: no stealing in the published co-Manager
                steal: false,
                shards: 1,
                noise_aware_alpha: None,
                seed,
            },
            &jobs,
        )
    };
    let single = run(Tenancy::SingleTenant, seed);
    let multi = run(Tenancy::MultiTenant, seed + 1);
    specs
        .iter()
        .enumerate()
        .map(|(i, &(q, l))| TenancyRow {
            label: format!("{q}Q/{l}L"),
            circuits: jobs[i].n_circuits,
            single_runtime: single.per_client[i].finish,
            multi_runtime: multi.per_client[i].finish,
            single_cps: single.per_client[i].cps,
            multi_cps: multi.per_client[i].cps,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_circuit_counts() {
        assert_eq!(epoch_circuits(5, 1), 1440);
        assert_eq!(epoch_circuits(5, 2), 2880);
        assert_eq!(epoch_circuits(5, 3), 4320);
        assert_eq!(epoch_circuits(7, 1), 2016);
        assert_eq!(epoch_circuits(7, 2), 4032);
        assert_eq!(epoch_circuits(7, 3), 6048);
    }

    /// The headline trend of Figs 3-5: within every layer count, more
    /// workers -> lower runtime and higher circuits/sec.
    #[test]
    fn figure_trends_hold() {
        let calib = Calibration::qiskit_like();
        for rows in [
            ibmq_figure(5, &calib, 1),
            ibmq_figure(7, &calib, 2),
            gcp_one_client_figure(5, &calib, 3),
        ] {
            for layers in [1, 2, 3] {
                let series: Vec<&FigureRow> =
                    rows.iter().filter(|r| r.layers == layers).collect();
                assert_eq!(series.len(), 3);
                assert!(
                    series[0].runtime > series[1].runtime
                        && series[1].runtime > series[2].runtime,
                    "layers {layers}: runtimes {:?}",
                    series.iter().map(|r| r.runtime).collect::<Vec<_>>()
                );
                assert!(series[2].cps > series[0].cps);
            }
        }
    }

    /// Fig 6's headline: the small job (5Q/1L) gains the most from
    /// multi-tenancy — large runtime reduction, multi-x cps gain — while
    /// the congested big jobs see little change (paper: 8.2% for 7Q/2L).
    #[test]
    fn multi_tenant_headline() {
        let rows = multi_tenant_figure(&Calibration::qiskit_like(), 7);
        assert_eq!(rows.len(), 4);
        let small = rows.iter().find(|r| r.label == "5Q/1L").unwrap();
        assert!(small.runtime_reduction_pct() > 30.0, "{}", small.runtime_reduction_pct());
        assert!(small.cps_gain() > 1.5, "{}", small.cps_gain());
        // the small job gains the most
        for r in &rows {
            assert!(
                small.cps_gain() >= r.cps_gain() - 1e-9,
                "{} gained more than 5Q/1L",
                r.label
            );
            // no client gets catastrophically worse
            assert!(
                r.multi_runtime <= r.single_runtime * 1.35,
                "{}: {} vs {}",
                r.label,
                r.multi_runtime,
                r.single_runtime
            );
        }
    }
}
