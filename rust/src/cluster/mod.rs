//! Cluster assembly: wire the co-Manager, workers, and clients together.
//!
//! * [`inproc`] — manager + N worker threads in one process (tests,
//!   quickstart, benches). Runs the identical manager/scheduler code;
//!   only the transport differs.
//! * [`tcp`] — the distributed deployment: the manager's RPC server,
//!   the manager→worker channels (multiplexed binary plane with JSON
//!   fallback), and the remote client.
//! * [`proto`] — the typed client↔manager wire messages
//!   (`SubmitRequest`/`SubmitResponse`, bank-status codecs).

pub mod inproc;
pub mod proto;
pub mod tcp;

pub use inproc::{InProcCluster, InProcClusterBuilder};
pub use proto::{SubmitRequest, SubmitResponse};
pub use tcp::{serve_manager, MuxWorkerChannel, RemoteClient};
