//! Cluster assembly: wire the co-Manager, workers, and clients together.
//!
//! * [`client`] — [`ClusterClient`], the unified surface every
//!   deployment shape implements (local manager, sharded manager,
//!   in-proc cluster, remote connection, principal federation).
//! * [`inproc`] — manager + N worker threads in one process (tests,
//!   quickstart, benches). Runs the identical manager/scheduler code;
//!   only the transport differs.
//! * [`tcp`] — the distributed deployment: the manager's dual-codec RPC
//!   server ([`serve_pool`] fronts a [`crate::coordinator::Manager`] or
//!   [`crate::coordinator::ShardManager`] alike), the manager→worker
//!   channels (multiplexed binary plane with JSON fallback), and the
//!   remote client (binary-first dial through one shared negotiate
//!   helper).
//! * [`principal`] — the principal manager federating agent managers:
//!   tenant routing, registration rebalancing, failover (DESIGN.md §18).
//! * [`proto`] — the typed client↔manager wire messages
//!   (`SubmitRequest`/`SubmitResponse`, bank-status codecs).

pub mod client;
pub mod inproc;
pub mod principal;
pub mod proto;
pub mod tcp;

pub use client::ClusterClient;
pub use inproc::{InProcCluster, InProcClusterBuilder};
pub use principal::Principal;
pub use proto::{SubmitRequest, SubmitResponse};
pub use tcp::{
    serve_manager, serve_pool, serve_pool_json, ManagedPool, MuxWorkerChannel, RemoteClient,
};
