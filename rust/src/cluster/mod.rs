//! Cluster assembly: wire the co-Manager, workers, and clients together.
//!
//! * [`inproc`] — manager + N worker threads in one process (tests,
//!   quickstart, benches). Runs the identical manager/scheduler code;
//!   only the transport differs.
//! * [`tcp`] — the distributed deployment: the manager's RPC server,
//!   the manager→worker RPC channel, and the remote client.

pub mod inproc;
pub mod tcp;

pub use inproc::{InProcCluster, InProcClusterBuilder};
pub use tcp::{serve_manager, RemoteClient};
