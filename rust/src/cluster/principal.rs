//! Principal manager: principal–agent federation over [`ClusterClient`]
//! backends (DESIGN.md §18).
//!
//! A [`Principal`] fronts several *agent* managers — local
//! [`crate::coordinator::Manager`]s or [`crate::coordinator::ShardManager`]s,
//! in-process clusters, or remote TCP managers — behind the same
//! [`ClusterClient`] surface the agents themselves implement, so
//! federations nest (a principal can be another principal's agent).
//!
//! Routing model:
//!
//! * **Sessions** bind lazily: a principal-side tenant id is mapped to
//!   an agent (round-robin over healthy agents) on its first submit, and
//!   sticks there so per-tenant WRR fairness accrues on one agent.
//! * **Banks** route by a principal-side bank id to the agent bank that
//!   backs them; wait/status/cancel follow the stored route.
//! * **Workers** register onto the agent with the fewest live workers —
//!   the principal's rebalancing keeps agent pools level as workers
//!   churn.
//! * **Failover**: an agent that fails a dial or a submit with a
//!   transport error is marked unhealthy and the tenant is re-bound to
//!   the next healthy agent (the submit retries there). Unhealthy
//!   agents are retried last, and re-marked healthy the first time they
//!   answer again. Banks already in flight on a dead agent are *not*
//!   replayed — their waits surface the agent's error, exactly like a
//!   lost worker inside one manager.
//!
//! Linearizability caveat: the principal serializes nothing across
//! agents. Two tenants on different agents see independent orderings,
//! and aggregate [`Principal::stats`] is a merge of per-agent snapshots
//! taken at different instants (counters are eventually consistent,
//! never double-counted). Per-tenant keys from different agents may
//! collide in the merged view — agent id spaces are independent — so
//! per-tenant rows in the federated stats are best-effort.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::client::ClusterClient;
use crate::circuit::QuClassiConfig;
use crate::coordinator::session::{ClientSession, SessionOps};
use crate::coordinator::{BankStatus, ManagerStats, WorkerChannel, WorkerId, WorkerProfile};
use crate::error::DqError;
use crate::model::exec::CircuitPair;

/// One federated agent: a named [`ClusterClient`] with a health flag.
struct Agent {
    name: String,
    backend: Arc<dyn ClusterClient>,
    healthy: AtomicBool,
}

/// A tenant's sticky binding onto one agent.
#[derive(Clone)]
struct Binding {
    agent: usize,
    ops: Arc<dyn SessionOps>,
    agent_client: u64,
}

/// A submitted bank's route back to the agent that runs it.
#[derive(Clone)]
struct BankRoute {
    agent: usize,
    ops: Arc<dyn SessionOps>,
    agent_bank: u64,
}

struct PrincipalInner {
    agents: Vec<Agent>,
    /// principal client id → agent binding (lazy, sticky).
    bindings: Mutex<HashMap<u64, Binding>>,
    /// principal bank id → agent bank route.
    banks: Mutex<HashMap<u64, BankRoute>>,
    next_client: AtomicU64,
    next_bank: AtomicU64,
    rr: AtomicU64,
    failovers: AtomicU64,
}

/// The principal manager: cheap to clone, shared across threads.
#[derive(Clone)]
pub struct Principal {
    inner: Arc<PrincipalInner>,
}

impl Principal {
    /// Federate the given named agents. Order matters only as the
    /// round-robin seed; health is tracked per agent at runtime.
    pub fn new(agents: Vec<(String, Arc<dyn ClusterClient>)>) -> Principal {
        Principal {
            inner: Arc::new(PrincipalInner {
                agents: agents
                    .into_iter()
                    .map(|(name, backend)| Agent {
                        name,
                        backend,
                        healthy: AtomicBool::new(true),
                    })
                    .collect(),
                bindings: Mutex::new(HashMap::new()),
                banks: Mutex::new(HashMap::new()),
                next_client: AtomicU64::new(1),
                next_bank: AtomicU64::new(1),
                rr: AtomicU64::new(0),
                failovers: AtomicU64::new(0),
            }),
        }
    }

    /// Number of federated agents.
    pub fn agents(&self) -> usize {
        self.inner.agents.len()
    }

    /// Agent names in registration order.
    pub fn agent_names(&self) -> Vec<String> {
        self.inner.agents.iter().map(|a| a.name.clone()).collect()
    }

    /// Per-agent health snapshot (same order as [`Principal::agent_names`]).
    pub fn health(&self) -> Vec<bool> {
        self.inner.agents.iter().map(|a| a.healthy.load(Ordering::Relaxed)).collect()
    }

    /// Tenant re-bindings forced by agent failures so far.
    pub fn failovers(&self) -> u64 {
        self.inner.failovers.load(Ordering::Relaxed)
    }

    /// A typed session on the federation. The tenant binds to an agent
    /// on first submit and stays there while the agent stays healthy.
    pub fn session(&self) -> ClientSession {
        let client = self.inner.next_client.fetch_add(1, Ordering::Relaxed);
        ClientSession::new(Arc::new(self.clone()), client)
    }

    /// Register a worker on the healthy agent with the fewest live
    /// workers (registration rebalancing). The returned id is scoped to
    /// that agent.
    pub fn register(
        &self,
        profile: WorkerProfile,
        channel: Arc<dyn WorkerChannel>,
    ) -> Result<WorkerId, DqError> {
        let mut order: Vec<usize> = (0..self.inner.agents.len()).collect();
        // fewest workers first; unhealthy agents sort last so capacity
        // lands where it can be scheduled
        order.sort_by_key(|&i| {
            let a = &self.inner.agents[i];
            (!a.healthy.load(Ordering::Relaxed), a.backend.worker_count())
        });
        let mut last = DqError::Unschedulable("principal has no agents".into());
        for idx in order {
            let agent = &self.inner.agents[idx];
            match agent.backend.register(profile.clone(), channel.clone()) {
                Ok(id) => {
                    agent.healthy.store(true, Ordering::Relaxed);
                    return Ok(id);
                }
                Err(e) => {
                    agent.healthy.store(false, Ordering::Relaxed);
                    last = e;
                }
            }
        }
        Err(last)
    }

    /// Merged counters across every agent that answers. Per-tenant rows
    /// are best-effort (agent id spaces are independent; see module
    /// docs); aggregate counters never double-count.
    pub fn stats(&self) -> ManagerStats {
        let mut out = ManagerStats::default();
        for a in &self.inner.agents {
            let Ok(s) = a.backend.stats() else { continue };
            out.submitted += s.submitted;
            out.completed += s.completed;
            out.dispatches += s.dispatches;
            out.requeues += s.requeues;
            out.evictions += s.evictions;
            out.cancelled += s.cancelled;
            out.steals += s.steals;
            out.pruned_tenants += s.pruned_tenants;
            out.retired.merge(&s.retired);
            for (client, t) in &s.per_tenant {
                out.per_tenant.entry(*client).or_default().merge(t);
            }
        }
        out
    }

    /// Live workers across all agents.
    pub fn worker_count(&self) -> usize {
        self.inner.agents.iter().map(|a| a.backend.worker_count()).sum()
    }

    /// Shut down every agent (the principal owns its federation's
    /// lifecycle; wrap agents in a no-op [`ClusterClient`] if not).
    pub fn shutdown(&self) {
        for a in &self.inner.agents {
            a.backend.shutdown();
        }
    }

    /// An existing binding, or a fresh one on a healthy agent. Healthy
    /// agents are tried first (round-robin from a moving seed); a second
    /// pass retries the sick ones so a recovered agent rejoins without
    /// operator action.
    fn bind(&self, pclient: u64) -> Result<Binding, DqError> {
        if let Some(b) = self.inner.bindings.lock().expect("bindings poisoned").get(&pclient) {
            return Ok(b.clone());
        }
        let n = self.inner.agents.len();
        if n == 0 {
            return Err(DqError::Unschedulable("principal has no agents".into()));
        }
        let start = self.inner.rr.fetch_add(1, Ordering::Relaxed) as usize;
        let mut last = DqError::Unschedulable("no healthy agent".into());
        for pass in 0..2 {
            for k in 0..n {
                let idx = (start + k) % n;
                let agent = &self.inner.agents[idx];
                let healthy = agent.healthy.load(Ordering::Relaxed);
                if (pass == 0) != healthy {
                    continue;
                }
                match agent.backend.session() {
                    Ok(session) => {
                        agent.healthy.store(true, Ordering::Relaxed);
                        let b = Binding {
                            agent: idx,
                            ops: session.ops(),
                            agent_client: session.id(),
                        };
                        return Ok(self
                            .inner
                            .bindings
                            .lock()
                            .expect("bindings poisoned")
                            .entry(pclient)
                            .or_insert(b)
                            .clone());
                    }
                    Err(e) => {
                        agent.healthy.store(false, Ordering::Relaxed);
                        crate::log_warn!(
                            "principal",
                            "agent '{}' failed session dial: {e}",
                            agent.name
                        );
                        last = e;
                    }
                }
            }
        }
        Err(last)
    }

    /// Drop a failed binding and mark its agent unhealthy.
    fn fail_over(&self, pclient: u64, agent: usize) {
        self.inner.agents[agent].healthy.store(false, Ordering::Relaxed);
        self.inner.bindings.lock().expect("bindings poisoned").remove(&pclient);
        self.inner.failovers.fetch_add(1, Ordering::Relaxed);
    }

    fn route(&self, pbank: u64) -> Result<BankRoute, DqError> {
        self.inner
            .banks
            .lock()
            .expect("banks poisoned")
            .get(&pbank)
            .cloned()
            .ok_or_else(|| DqError::Protocol(format!("unknown bank {pbank}")))
    }
}

/// A fault that makes the *agent* (not a bank) suspect on the dial and
/// submit paths. `Io` is a refused/torn connection; `Timeout` is the
/// partition shape — packets silently dropped, the RPC deadline fires
/// instead of the socket erroring. Both must re-bind the tenant
/// (PR 10 satellite: Timeout previously wedged tenants on a partitioned
/// agent).
fn is_agent_fault(e: &DqError) -> bool {
    matches!(e, DqError::Io(_) | DqError::Timeout(_))
}

/// A transport-level failure on the *wait* path. Deliberately Io-only:
/// a waited bank timing out is a legitimate bank-level outcome (slow
/// fleet, bounded deadline) and says nothing about the agent's health.
fn is_transport(e: &DqError) -> bool {
    matches!(e, DqError::Io(_))
}

impl SessionOps for Principal {
    fn submit(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<u64, DqError> {
        let attempts = self.inner.agents.len().max(1);
        let mut last = DqError::Unschedulable("principal has no agents".into());
        for _ in 0..attempts {
            let b = self.bind(client)?;
            match b.ops.submit(b.agent_client, config, pairs) {
                Ok(agent_bank) => {
                    let pbank = self.inner.next_bank.fetch_add(1, Ordering::Relaxed);
                    self.inner.banks.lock().expect("banks poisoned").insert(
                        pbank,
                        BankRoute { agent: b.agent, ops: b.ops, agent_bank },
                    );
                    return Ok(pbank);
                }
                Err(e) if is_agent_fault(&e) => {
                    crate::log_warn!(
                        "principal",
                        "agent '{}' lost mid-submit; re-binding tenant {client}: {e}",
                        self.inner.agents[b.agent].name
                    );
                    self.fail_over(client, b.agent);
                    last = e;
                }
                Err(e) => return Err(e),
            }
        }
        Err(last)
    }

    fn wait(&self, bank: u64, timeout: Option<Duration>) -> Result<Vec<f32>, DqError> {
        let r = self.route(bank)?;
        let res = r.ops.wait(r.agent_bank, timeout);
        if let Err(e) = &res {
            if is_transport(e) {
                self.inner.agents[r.agent].healthy.store(false, Ordering::Relaxed);
            }
        }
        res
    }

    fn status(&self, bank: u64) -> Result<BankStatus, DqError> {
        let r = self.route(bank)?;
        r.ops.status(r.agent_bank)
    }

    fn cancel(&self, bank: u64) -> Result<usize, DqError> {
        let r = self.route(bank)?;
        r.ops.cancel(r.agent_bank)
    }
}

impl ClusterClient for Principal {
    fn session(&self) -> Result<ClientSession, DqError> {
        Ok(Principal::session(self))
    }

    fn register(
        &self,
        profile: WorkerProfile,
        channel: Arc<dyn WorkerChannel>,
    ) -> Result<WorkerId, DqError> {
        Principal::register(self, profile, channel)
    }

    fn stats(&self) -> Result<ManagerStats, DqError> {
        Ok(Principal::stats(self))
    }

    fn worker_count(&self) -> usize {
        Principal::worker_count(self)
    }

    fn shutdown(&self) {
        Principal::shutdown(self)
    }

    fn describe(&self) -> String {
        format!("principal ({} agents, {} workers)", self.agents(), self.worker_count())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::InProcCluster;

    fn inproc_agent(qubits: usize) -> Arc<dyn ClusterClient> {
        Arc::new(InProcCluster::builder().workers(&[qubits]).build().unwrap())
    }

    fn pairs(n: usize) -> Vec<CircuitPair> {
        vec![(vec![0.25; 4], vec![0.5; 4]); n]
    }

    #[test]
    fn principal_routes_and_completes_across_agents() {
        let p = Principal::new(vec![
            ("east".into(), inproc_agent(5)),
            ("west".into(), inproc_agent(5)),
        ]);
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        // several tenants: round-robin binding spreads them over agents
        for _ in 0..4 {
            let session = p.session();
            let fids = session.execute(cfg, &pairs(3)).unwrap();
            assert_eq!(fids.len(), 3);
        }
        let stats = p.stats();
        assert_eq!(stats.submitted, 12);
        assert_eq!(stats.completed, 12);
        assert_eq!(p.worker_count(), 2);
        assert_eq!(p.failovers(), 0);
        p.shutdown();
    }

    /// An agent whose transport is down: sessions dial fine but every
    /// submit fails with Io.
    struct DeadOps;

    impl SessionOps for DeadOps {
        fn submit(
            &self,
            _client: u64,
            _config: QuClassiConfig,
            _pairs: &[CircuitPair],
        ) -> Result<u64, DqError> {
            Err(DqError::Io("agent unreachable".into()))
        }
        fn wait(&self, _bank: u64, _t: Option<Duration>) -> Result<Vec<f32>, DqError> {
            Err(DqError::Io("agent unreachable".into()))
        }
        fn status(&self, _bank: u64) -> Result<BankStatus, DqError> {
            Err(DqError::Io("agent unreachable".into()))
        }
        fn cancel(&self, _bank: u64) -> Result<usize, DqError> {
            Err(DqError::Io("agent unreachable".into()))
        }
    }

    struct DeadAgent;

    impl ClusterClient for DeadAgent {
        fn session(&self) -> Result<ClientSession, DqError> {
            Ok(ClientSession::new(Arc::new(DeadOps), 1))
        }
        fn register(
            &self,
            _profile: WorkerProfile,
            _channel: Arc<dyn WorkerChannel>,
        ) -> Result<WorkerId, DqError> {
            Err(DqError::Io("agent unreachable".into()))
        }
        fn stats(&self) -> Result<ManagerStats, DqError> {
            Err(DqError::Io("agent unreachable".into()))
        }
        fn worker_count(&self) -> usize {
            0
        }
        fn shutdown(&self) {}
        fn describe(&self) -> String {
            "dead agent".into()
        }
    }

    #[test]
    fn principal_fails_over_to_healthy_agent() {
        // rr seed starts at agent 0 — the dead one — so the first submit
        // exercises the failover path deterministically.
        let p = Principal::new(vec![
            ("dead".into(), Arc::new(DeadAgent) as Arc<dyn ClusterClient>),
            ("live".into(), inproc_agent(5)),
        ]);
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let session = p.session();
        let fids = session.execute(cfg, &pairs(4)).unwrap();
        assert_eq!(fids.len(), 4);
        assert_eq!(p.failovers(), 1);
        assert_eq!(p.health(), vec![false, true]);
        // subsequent tenants bind straight to the live agent
        let fids2 = p.session().execute(cfg, &pairs(2)).unwrap();
        assert_eq!(fids2.len(), 2);
        assert_eq!(p.failovers(), 1);
        p.shutdown();
    }

    #[test]
    fn registration_balances_onto_emptier_agent() {
        // agent "big" starts with one worker; a fresh registration must
        // land on "empty" (fewest live workers wins).
        let empty: Arc<dyn ClusterClient> = Arc::new(
            crate::coordinator::Manager::new(crate::coordinator::ManagerConfig::default()),
        );
        let big = inproc_agent(5);
        let p = Principal::new(vec![("big".into(), big.clone()), ("empty".into(), empty.clone())]);
        struct NoopChannel;
        impl crate::coordinator::WorkerChannel for NoopChannel {
            fn execute(
                &self,
                _config: &QuClassiConfig,
                _pairs: &[CircuitPair],
            ) -> Result<Vec<f32>, DqError> {
                Ok(Vec::new())
            }
        }
        p.register(WorkerProfile::new(7), Arc::new(NoopChannel)).unwrap();
        assert_eq!(empty.worker_count(), 1);
        assert_eq!(big.worker_count(), 1);
        assert_eq!(p.worker_count(), 2);
        p.shutdown();
    }

    /// [`SessionOps`] shim that delegates to a real agent until the
    /// partition flag flips, then *times out* every call — the packet-
    /// dropping partition shape, as opposed to [`DeadOps`]' hard refusal.
    struct PartitionableOps {
        inner: Arc<dyn SessionOps>,
        inner_client: u64,
        partitioned: Arc<AtomicBool>,
    }

    impl PartitionableOps {
        fn check(&self) -> Result<(), DqError> {
            if self.partitioned.load(Ordering::Relaxed) {
                Err(DqError::Timeout("agent partitioned: rpc deadline elapsed".into()))
            } else {
                Ok(())
            }
        }
    }

    impl SessionOps for PartitionableOps {
        fn submit(
            &self,
            _client: u64,
            config: QuClassiConfig,
            pairs: &[CircuitPair],
        ) -> Result<u64, DqError> {
            self.check()?;
            self.inner.submit(self.inner_client, config, pairs)
        }
        fn wait(&self, bank: u64, t: Option<Duration>) -> Result<Vec<f32>, DqError> {
            self.check()?;
            self.inner.wait(bank, t)
        }
        fn status(&self, bank: u64) -> Result<BankStatus, DqError> {
            self.check()?;
            self.inner.status(bank)
        }
        fn cancel(&self, bank: u64) -> Result<usize, DqError> {
            self.check()?;
            self.inner.cancel(bank)
        }
    }

    struct PartitionableAgent {
        backend: Arc<dyn ClusterClient>,
        partitioned: Arc<AtomicBool>,
    }

    impl ClusterClient for PartitionableAgent {
        fn session(&self) -> Result<ClientSession, DqError> {
            if self.partitioned.load(Ordering::Relaxed) {
                return Err(DqError::Timeout("agent partitioned: dial deadline elapsed".into()));
            }
            let inner = self.backend.session()?;
            let ops = Arc::new(PartitionableOps {
                inner: inner.ops(),
                inner_client: inner.id(),
                partitioned: self.partitioned.clone(),
            });
            Ok(ClientSession::new(ops, inner.id()))
        }
        fn register(
            &self,
            profile: WorkerProfile,
            channel: Arc<dyn WorkerChannel>,
        ) -> Result<WorkerId, DqError> {
            self.backend.register(profile, channel)
        }
        fn stats(&self) -> Result<ManagerStats, DqError> {
            self.backend.stats()
        }
        fn worker_count(&self) -> usize {
            self.backend.worker_count()
        }
        fn shutdown(&self) {
            self.backend.shutdown()
        }
        fn describe(&self) -> String {
            "partitionable agent".into()
        }
    }

    /// Regression (PR 10 satellite): an agent that *times out* — a
    /// network partition, not a refused connection — must trip failover
    /// exactly like a hard `Io` fault. Previously only `Io` re-bound the
    /// tenant, so a partitioned agent wedged everyone stuck to it.
    #[test]
    fn partitioned_agent_fails_over_mid_churn() {
        let partitioned = Arc::new(AtomicBool::new(false));
        let flaky: Arc<dyn ClusterClient> = Arc::new(PartitionableAgent {
            backend: inproc_agent(5),
            partitioned: partitioned.clone(),
        });
        // rr seed starts at agent 0 — the partitionable one — so the
        // first tenant deterministically binds there while it is healthy.
        let p = Principal::new(vec![("flaky".into(), flaky), ("live".into(), inproc_agent(5))]);
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let session = p.session();
        assert_eq!(session.execute(cfg, &pairs(2)).unwrap().len(), 2);
        assert_eq!(p.failovers(), 0);

        // Partition mid-churn: the tenant's sticky binding is now stale.
        partitioned.store(true, Ordering::Relaxed);
        // The same tenant's next submit times out on the stale binding,
        // fails over, and completes on the healthy sibling.
        assert_eq!(session.execute(cfg, &pairs(3)).unwrap().len(), 3);
        assert!(p.failovers() >= 1, "Timeout must count as an agent fault");
        assert_eq!(p.health(), vec![false, true]);

        // Fresh tenants bind straight to the live agent — no extra
        // failovers while the partition persists.
        let before = p.failovers();
        assert_eq!(p.session().execute(cfg, &pairs(2)).unwrap().len(), 2);
        assert_eq!(p.failovers(), before);
        p.shutdown();
    }

    #[test]
    fn unknown_bank_is_a_typed_protocol_error() {
        let p = Principal::new(vec![("only".into(), inproc_agent(5))]);
        assert!(matches!(
            SessionOps::wait(&p, 999, None),
            Err(DqError::Protocol(_))
        ));
        p.shutdown();
    }
}
