//! Distributed deployment over TCP: manager RPC server, manager→worker
//! channel, and the remote client.
//!
//! Message flow (client↔manager payloads are the typed pairs in
//! [`super::proto`]; each line exists on both planes — framed JSON
//! through the `net::rpc` envelope, and the binary mux plane through the
//! interned op ids in [`crate::wire::bin`]):
//!
//! ```text
//! worker  -> manager : register {max_qubits, addr, cru, threads} -> {worker_id}
//! worker  -> manager : heartbeat {worker_id, cru}
//! client  -> manager : submit_bank <SubmitRequest>     -> <SubmitResponse>
//! client  -> manager : wait_bank {bank, timeout_ms?}   -> {fids}
//! client  -> manager : bank_status {bank}              -> <BankStatus>
//! client  -> manager : cancel_bank {bank}              -> {drained}
//! manager -> worker  : execute {circuits}              -> {fids}
//! ```
//!
//! The binary plane additionally streams: `subscribe_bank {bank}` opens
//! a push stream on its correlation id, and every completed circuit
//! arrives as an unsolicited `BankEvent` frame (DESIGN.md §19) — a
//! binary client's `try_poll`/bounded `wait` are answered from the
//! locally accumulated events with **zero** `bank_status` polls on the
//! wire. JSON peers keep the poll loop.
//!
//! **Negotiation is one code path.** Both dial directions — the
//! manager's dial-back to a registering worker and
//! [`RemoteClient::connect`] — go through
//! [`crate::net::rpc::dial_plane`]: try the mux `DQMX` handshake first,
//! fall back to framed JSON when the peer predates the binary plane.
//! [`serve_pool`] serves both codecs on one port (the first four bytes
//! of a connection disambiguate).
//!
//! Errors round-trip typed on either plane: a bank the manager fails
//! with `DqError::Unschedulable` (or a client cancels to `Cancelled`)
//! surfaces as that same variant on the remote side.
//!
//! Trust model: the protocol is *cooperative* — client ids, bank ids,
//! and worker registration are unauthenticated sequential handles, so
//! any peer that can reach the manager can wait on, poll, or cancel any
//! bank. Deploy on a trusted network segment (DESIGN.md §12).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::proto::{self, SubmitRequest, SubmitResponse};
use crate::circuit::QuClassiConfig;
use crate::coordinator::job::CircuitJob;
use crate::coordinator::session::{ClientSession, SessionOps};
use crate::coordinator::{
    BankEvent, BankStatus, BankWatcher, Manager, ManagerStats, ShardManager, WorkerChannel,
    WorkerId, WorkerProfile,
};
use crate::error::DqError;
use crate::model::exec::{CircuitExecutor, CircuitPair};
use crate::net::mux::Pusher;
use crate::net::rpc::{dial_plane, Plane};
use crate::net::{Mux, MuxConfig, MuxService, RpcClient, RpcServer};
use crate::wire::{bin, Value};

/// Build the per-dispatch job list a worker executes (ids are
/// per-dispatch ordinals; the manager's bookkeeping stays local).
fn dispatch_jobs(config: &QuClassiConfig, pairs: &[CircuitPair]) -> Vec<CircuitJob> {
    pairs
        .iter()
        .enumerate()
        .map(|(i, (thetas, data))| CircuitJob {
            id: i as u64,
            client: 0,
            bank: 0,
            index: i,
            config: *config,
            thetas: thetas.clone(),
            data: data.clone(),
        })
        .collect()
}

/// Manager→worker channel over JSON RPC — the fallback plane. Executed
/// on the worker's outbox dispatcher thread (DESIGN.md §13): the
/// blocking RPC round trip ties up only this worker's outbox, so a slow
/// or unreachable remote worker never delays dispatch to its siblings.
///
/// The connection self-heals: a connection-level failure drops the
/// socket and redials under capped backoff + jitter (up to 3 attempts
/// per execute), so a transient network blip or worker restart is not
/// immediately escalated into a lost worker.
struct RpcWorkerChannel {
    addr: String,
    client: Mutex<Option<Arc<RpcClient>>>,
}

impl RpcWorkerChannel {
    fn new(addr: String, client: Arc<RpcClient>) -> RpcWorkerChannel {
        RpcWorkerChannel { addr, client: Mutex::new(Some(client)) }
    }
}

impl WorkerChannel for RpcWorkerChannel {
    fn execute(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        let circuits: Vec<Value> =
            dispatch_jobs(config, pairs).iter().map(CircuitJob::to_wire).collect();
        let params = Value::obj().with("circuits", circuits);
        let mut last = DqError::Io(format!("worker {} unreachable", self.addr));
        for _ in 0..3 {
            let mut guard = self.client.lock().expect("rpc channel poisoned");
            if guard.is_none() {
                // RpcClient::connect retries under capped backoff +
                // jitter for its whole budget before giving up.
                match RpcClient::connect(self.addr.as_str(), Duration::from_secs(2)) {
                    Ok(c) => *guard = Some(Arc::new(c)),
                    Err(e) => {
                        last = e;
                        continue;
                    }
                }
            }
            let client = guard.as_ref().expect("client ensured above");
            match client.call("execute", params.clone()) {
                Ok(resp) => return Ok(resp.req_f32_vec("fids")?),
                Err(DqError::Io(msg)) => {
                    // Connection-level failure: drop the socket, redial.
                    *guard = None;
                    last = DqError::Io(msg);
                }
                Err(e) => return Err(e),
            }
        }
        Err(last)
    }
}

/// Manager→worker channel over the multiplexed binary plane. Async: the
/// outbox dispatcher enqueues the request and returns immediately; the
/// completion arrives on the mux transport threads. A torn-down
/// connection (idle timeout, peer death) fails in flight and future
/// requests with [`DqError::WorkerLost`], feeding the existing
/// requeue/eviction path.
pub struct MuxWorkerChannel {
    mux: Arc<Mux>,
    conn: u64,
}

impl MuxWorkerChannel {
    pub fn new(mux: Arc<Mux>, conn: u64) -> MuxWorkerChannel {
        MuxWorkerChannel { mux, conn }
    }
}

impl WorkerChannel for MuxWorkerChannel {
    fn execute(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        let payload = bin::encode_jobs(&dispatch_jobs(config, pairs));
        let bytes = self.mux.call(self.conn, bin::OP_EXECUTE, payload)?;
        bin::decode_fids(&bytes)
    }

    fn is_async(&self) -> bool {
        true
    }

    fn execute_async(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
        done: Box<dyn FnOnce(Result<Vec<f32>, DqError>) + Send + 'static>,
    ) {
        let payload = bin::encode_jobs(&dispatch_jobs(config, pairs));
        self.mux.request(
            self.conn,
            bin::OP_EXECUTE,
            payload,
            Box::new(move |res| done(res.and_then(|bytes| bin::decode_fids(&bytes)))),
        );
    }
}

/// The manager surface the TCP plane serves. Implemented by the
/// single-shard [`Manager`] and the sharded [`ShardManager`], so one
/// server (and one wire protocol) fronts either deployment — remote
/// peers cannot tell how many shards answer them.
pub trait ManagedPool: Clone + Send + Sync + 'static {
    /// Register a dialed-back worker channel; returns the worker id.
    fn register(&self, profile: WorkerProfile, channel: Arc<dyn WorkerChannel>) -> WorkerId;
    /// Record a worker heartbeat.
    fn heartbeat(&self, worker: WorkerId, cru: f64) -> Result<(), DqError>;
    /// Allocate a tenant id.
    fn new_client(&self) -> u64;
    /// Enqueue a bank of circuits.
    fn submit_bank(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<u64, DqError>;
    /// Block for a bank's fidelities (manager-configured timeout).
    fn wait_bank(&self, bank: u64) -> Result<Vec<f32>, DqError>;
    /// Block for a bank's fidelities with an explicit deadline.
    fn wait_bank_timeout(&self, bank: u64, timeout: Duration) -> Result<Vec<f32>, DqError>;
    /// Non-blocking bank snapshot.
    fn bank_status(&self, bank: u64) -> Option<BankStatus>;
    /// Was the bank cancelled (tombstone check)?
    fn bank_cancelled(&self, bank: u64) -> bool;
    /// Cancel a bank; returns queued circuits drained.
    fn cancel_bank(&self, bank: u64) -> usize;
    /// Register a progress watcher on a bank (false for a bank the pool
    /// has never seen). Backs the binary plane's `subscribe_bank`.
    fn watch_bank(&self, bank: u64, w: BankWatcher) -> bool;
    /// Aggregate counters.
    fn stats(&self) -> ManagerStats;
    /// Live worker count.
    fn worker_count(&self) -> usize;
    /// Pending circuit count.
    fn queue_len(&self) -> usize;
}

macro_rules! impl_managed_pool {
    ($ty:ty) => {
        impl ManagedPool for $ty {
            fn register(
                &self,
                profile: WorkerProfile,
                channel: Arc<dyn WorkerChannel>,
            ) -> WorkerId {
                <$ty>::register(self, profile, channel)
            }
            fn heartbeat(&self, worker: WorkerId, cru: f64) -> Result<(), DqError> {
                <$ty>::heartbeat(self, worker, cru)
            }
            fn new_client(&self) -> u64 {
                <$ty>::new_client(self)
            }
            fn submit_bank(
                &self,
                client: u64,
                config: QuClassiConfig,
                pairs: &[CircuitPair],
            ) -> Result<u64, DqError> {
                <$ty>::submit_bank(self, client, config, pairs)
            }
            fn wait_bank(&self, bank: u64) -> Result<Vec<f32>, DqError> {
                <$ty>::wait_bank(self, bank)
            }
            fn wait_bank_timeout(
                &self,
                bank: u64,
                timeout: Duration,
            ) -> Result<Vec<f32>, DqError> {
                <$ty>::wait_bank_timeout(self, bank, timeout)
            }
            fn bank_status(&self, bank: u64) -> Option<BankStatus> {
                <$ty>::bank_status(self, bank)
            }
            fn bank_cancelled(&self, bank: u64) -> bool {
                <$ty>::bank_cancelled(self, bank)
            }
            fn cancel_bank(&self, bank: u64) -> usize {
                <$ty>::cancel_bank(self, bank)
            }
            fn watch_bank(&self, bank: u64, w: BankWatcher) -> bool {
                <$ty>::watch_bank(self, bank, w)
            }
            fn stats(&self) -> ManagerStats {
                <$ty>::stats(self)
            }
            fn worker_count(&self) -> usize {
                <$ty>::worker_count(self)
            }
            fn queue_len(&self) -> usize {
                <$ty>::queue_len(self)
            }
        }
    };
}

impl_managed_pool!(Manager);
impl_managed_pool!(ShardManager);

/// The JSON side of [`serve_pool`]: the classic envelope handler, shared
/// by the dual-codec and JSON-only servers.
fn json_handler<M: ManagedPool>(pool: M) -> Arc<dyn crate::net::RpcHandler> {
    let mux: Mutex<Option<Arc<Mux>>> = Mutex::new(None);
    Arc::new(move |op: &str, params: &Value| -> Result<Value, DqError> {
        match op {
            "register" => {
                let max_qubits = params.req_usize("max_qubits")?;
                let addr = params.req_str("addr")?.to_string();
                let cru = params.req_f64("cru").unwrap_or(0.0);
                // Optional thread budget (older workers omit it): sizes
                // dispatch batches to the worker's real parallelism.
                let threads = params.get("threads").and_then(Value::as_usize).unwrap_or(1);
                let m = {
                    let mut slot = mux.lock().expect("mux slot poisoned");
                    slot.get_or_insert_with(|| Mux::new(MuxConfig::default())).clone()
                };
                // Binary-first dial-back through the shared negotiate
                // helper; a worker that predates the binary plane gets
                // the classic JSON channel.
                let channel: Arc<dyn WorkerChannel> =
                    match dial_plane(&m, addr.as_str(), Duration::from_secs(5))
                        .map_err(|e| DqError::Io(format!("dial worker back: {e}")))?
                    {
                        Plane::Bin { mux, conn, .. } => Arc::new(MuxWorkerChannel::new(mux, conn)),
                        Plane::Json(rpc) => Arc::new(RpcWorkerChannel::new(addr, rpc)),
                    };
                let id = pool
                    .register(WorkerProfile::new(max_qubits).cru(cru).threads(threads), channel);
                Ok(Value::obj().with("worker_id", id))
            }
            "heartbeat" => {
                let id = params.req_u64("worker_id")?;
                let cru = params.req_f64("cru").unwrap_or(0.0);
                pool.heartbeat(id, cru)?;
                Ok(Value::obj())
            }
            "new_client" => Ok(Value::obj().with("client", pool.new_client())),
            "submit_bank" => {
                let req = SubmitRequest::from_wire(params)?;
                let bank = pool.submit_bank(req.client, req.config, &req.pairs)?;
                Ok(SubmitResponse { bank, total: req.pairs.len() }.to_wire())
            }
            "wait_bank" => {
                let bank = params.req_u64("bank")?;
                let fids = match params.get("timeout_ms").and_then(Value::as_u64) {
                    Some(ms) => pool.wait_bank_timeout(bank, Duration::from_millis(ms))?,
                    None => pool.wait_bank(bank)?,
                };
                Ok(Value::obj().with("fids", fids.as_slice()))
            }
            "bank_status" => {
                let bank = params.req_u64("bank")?;
                let status = pool.bank_status(bank).ok_or_else(|| status_error(&pool, bank))?;
                Ok(proto::bank_status_to_wire(&status))
            }
            "cancel_bank" => {
                let bank = params.req_u64("bank")?;
                Ok(Value::obj().with("drained", pool.cancel_bank(bank)))
            }
            "stats" => {
                // The counters (incl. per-tenant wait histograms and
                // steal/retention fields) serialize through the shared
                // proto codec; the live pool/queue gauges ride on top.
                Ok(proto::manager_stats_to_wire(&pool.stats())
                    .with("workers", pool.worker_count())
                    .with("queue", pool.queue_len()))
            }
            other => Err(DqError::Protocol(format!("manager: unknown op '{other}'"))),
        }
    })
}

/// The binary side of [`serve_pool`]: the same ops keyed by the interned
/// ids in [`crate::wire::bin`], served from the shared mux park. Fast
/// ops run inline on the park's transport thread; `wait_bank` is
/// deferred to a transient thread (it blocks for up to the bank
/// timeout); `subscribe_bank` opens a push stream wired straight into
/// the bank store's watcher list.
struct PoolBinService<M: ManagedPool> {
    pool: M,
}

impl<M: ManagedPool> MuxService for PoolBinService<M> {
    fn handle(&self, op: u32, payload: &[u8]) -> Result<Vec<u8>, DqError> {
        let pool = &self.pool;
        match op {
            bin::OP_NEW_CLIENT => Ok(bin::encode_u64(pool.new_client())),
            bin::OP_SUBMIT_BANK => {
                let req = bin::decode_submit_request(payload)?;
                let bank = pool.submit_bank(req.client, req.config, &req.pairs)?;
                Ok(bin::encode_submit_response(&SubmitResponse { bank, total: req.pairs.len() }))
            }
            bin::OP_WAIT_BANK => {
                let (bank, timeout_ms) = bin::decode_wait_request(payload)?;
                let fids = match timeout_ms {
                    Some(ms) => pool.wait_bank_timeout(bank, Duration::from_millis(ms))?,
                    None => pool.wait_bank(bank)?,
                };
                Ok(bin::encode_fids(&fids))
            }
            bin::OP_BANK_STATUS => {
                let bank = bin::decode_u64(payload)?;
                let status = pool.bank_status(bank).ok_or_else(|| status_error(pool, bank))?;
                Ok(bin::encode_bank_status(&status))
            }
            bin::OP_CANCEL_BANK => {
                let bank = bin::decode_u64(payload)?;
                Ok(bin::encode_u64(pool.cancel_bank(bank) as u64))
            }
            bin::OP_STATS => Ok(bin::encode_pool_stats(
                &pool.stats(),
                pool.worker_count() as u64,
                pool.queue_len() as u64,
            )),
            other => Err(DqError::Protocol(format!("manager: unknown binary op {other}"))),
        }
    }

    /// `wait_bank` blocks up to the bank timeout — run it off the park's
    /// transport thread so one waiting client never stalls the plane.
    fn defer(&self, op: u32) -> bool {
        op == bin::OP_WAIT_BANK
    }

    /// `subscribe_bank {bank}` — register a store watcher that encodes
    /// every [`BankEvent`] as a push frame. Terminal events also finish
    /// the stream (OK for `Done`, the typed error otherwise), closing
    /// the client-side correlation id. The watcher runs under the bank
    /// store's lock and only appends to the connection's out-queue.
    fn open_stream(&self, op: u32, payload: &[u8], pusher: Pusher) -> Option<Result<(), DqError>> {
        if op != bin::OP_SUBSCRIBE_BANK {
            return None;
        }
        let bank = match bin::decode_u64(payload) {
            Ok(b) => b,
            Err(e) => return Some(Err(e)),
        };
        let watcher: BankWatcher = Box::new(move |ev: &BankEvent| {
            pusher.push(&bin::encode_bank_event(ev));
            match ev {
                BankEvent::Fid { .. } => {}
                BankEvent::Done => pusher.finish(Ok(Vec::new())),
                BankEvent::Failed(e) => pusher.finish(Err(e.clone())),
                BankEvent::Cancelled => {
                    pusher.finish(Err(DqError::Cancelled(format!("bank {bank} cancelled"))))
                }
            }
        });
        if self.pool.watch_bank(bank, watcher) {
            Some(Ok(()))
        } else {
            Some(Err(DqError::Protocol(format!("unknown bank {bank}"))))
        }
    }
}

/// The typed error for a missing bank: cancelled tombstones surface as
/// [`DqError::Cancelled`], anything else is an unknown id.
fn status_error<M: ManagedPool>(pool: &M, bank: u64) -> DqError {
    if pool.bank_cancelled(bank) {
        DqError::Cancelled(format!("bank {bank} cancelled"))
    } else {
        DqError::Protocol(format!("unknown bank {bank}"))
    }
}

/// Expose a [`Manager`] on a TCP address. Returns the server handle
/// (drop to stop accepting). Shorthand for [`serve_pool`].
pub fn serve_manager(manager: Manager, listen: &str) -> std::io::Result<RpcServer> {
    serve_pool(manager, listen)
}

/// Expose any [`ManagedPool`] — a [`Manager`] or a [`ShardManager`] — on
/// a TCP address, serving both codecs on one port: connections opening
/// with the mux magic get the binary plane, everything else framed JSON.
///
/// Worker dial-back likewise negotiates the binary plane first: one
/// shared [`Mux`] (created lazily on the first registration) multiplexes
/// every worker that speaks it; a worker whose handshake fails — an old
/// JSON-only build — gets the classic [`RpcClient`] channel instead.
pub fn serve_pool<M: ManagedPool>(pool: M, listen: &str) -> std::io::Result<RpcServer> {
    RpcServer::serve_bin(listen, json_handler(pool.clone()), Arc::new(PoolBinService { pool }))
}

/// [`serve_pool`] restricted to framed JSON — the legacy/debug surface.
/// Dialers that try the binary handshake fall back cleanly, exactly as
/// against a pre-binary build.
pub fn serve_pool_json<M: ManagedPool>(pool: M, listen: &str) -> std::io::Result<RpcServer> {
    RpcServer::serve(listen, json_handler(pool))
}

/// Locally accumulated view of a subscribed bank: filled in by the push
/// stream's events, consulted by `status`/`wait` without touching the
/// wire.
struct WatchState {
    fids: Vec<Option<f32>>,
    completed: usize,
    terminal: Option<Result<(), DqError>>,
}

/// Client-side sink for one bank's `subscribe_bank` push stream.
struct BankWatch {
    state: Mutex<WatchState>,
    cv: Condvar,
}

impl BankWatch {
    fn new(total: usize) -> BankWatch {
        BankWatch {
            state: Mutex::new(WatchState {
                fids: vec![None; total],
                completed: 0,
                terminal: None,
            }),
            cv: Condvar::new(),
        }
    }

    /// Fold one pushed event into the local view (push frames arrive in
    /// emit order — the completion runner preserves it).
    fn apply(&self, ev: &BankEvent) {
        let mut s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        match ev {
            BankEvent::Fid { index, fid, .. } => {
                let i = *index;
                if i < s.fids.len() && s.fids[i].is_none() {
                    s.fids[i] = Some(*fid);
                    s.completed += 1;
                }
            }
            BankEvent::Done => {
                if s.terminal.is_none() {
                    s.terminal = Some(Ok(()));
                }
            }
            BankEvent::Failed(e) => {
                if s.terminal.is_none() {
                    s.terminal = Some(Err(e.clone()));
                }
            }
            BankEvent::Cancelled => {
                if s.terminal.is_none() {
                    s.terminal = Some(Err(DqError::Cancelled("bank cancelled".to_string())));
                }
            }
        }
        drop(s);
        self.cv.notify_all();
    }

    /// Terminal from the stream's done callback (first write wins — the
    /// terminal *event* usually lands first via `apply`).
    fn finish(&self, res: Result<(), DqError>) {
        let mut s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if s.terminal.is_none() {
            s.terminal = Some(res);
        }
        drop(s);
        self.cv.notify_all();
    }

    /// Block until the bank reaches a terminal state or `timeout`
    /// elapses. Returns whether a terminal state was reached.
    fn wait_terminal(&self, timeout: Duration) -> bool {
        let mut s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let deadline = std::time::Instant::now() + timeout;
        while s.terminal.is_none() {
            let left = match deadline.checked_duration_since(std::time::Instant::now()) {
                Some(d) if !d.is_zero() => d,
                _ => return false,
            };
            let (guard, _) = self
                .cv
                .wait_timeout(s, left)
                .unwrap_or_else(|e| e.into_inner());
            s = guard;
        }
        true
    }

    /// Snapshot the local view as a [`BankStatus`] (zero network traffic).
    fn status(&self) -> BankStatus {
        let s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        BankStatus {
            pending: s.terminal.is_none(),
            completed: s.completed,
            total: s.fids.len(),
            partial_fids: s.fids.clone(),
            recovered: false,
        }
    }
}

/// [`SessionOps`] over the negotiated connection: the transport behind
/// remote [`ClientSession`]s. Every op exists on both planes; the match
/// arms are the *entire* divergence between binary and JSON clients.
///
/// On a binary plane that negotiated [`bin::FEAT_PUSH`], every submitted
/// bank is immediately subscribed: partial fidelities stream in as push
/// frames and `status`/bounded `wait` are answered from the local
/// [`BankWatch`] — zero `bank_status` polls on the wire
/// (`status_polls` counts the network fallbacks; the push test pins it
/// at 0).
struct RemoteOps {
    plane: Arc<Plane>,
    watches: Mutex<HashMap<u64, Arc<BankWatch>>>,
    status_polls: Arc<AtomicU64>,
}

impl RemoteOps {
    fn new(plane: Arc<Plane>, status_polls: Arc<AtomicU64>) -> RemoteOps {
        RemoteOps { plane, watches: Mutex::new(HashMap::new()), status_polls }
    }

    fn bin_call(mux: &Arc<Mux>, conn: u64, op: u32, payload: Vec<u8>) -> Result<Vec<u8>, DqError> {
        mux.call(conn, op, payload)
    }

    fn watch(&self, bank: u64) -> Option<Arc<BankWatch>> {
        self.watches.lock().unwrap_or_else(|e| e.into_inner()).get(&bank).cloned()
    }

    fn drop_watch(&self, bank: u64) {
        self.watches.lock().unwrap_or_else(|e| e.into_inner()).remove(&bank);
    }

    /// Open the push stream for a freshly submitted bank.
    fn subscribe(&self, mux: &Arc<Mux>, conn: u64, bank: u64, total: usize) {
        let watch = Arc::new(BankWatch::new(total));
        self.watches
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(bank, watch.clone());
        let apply_watch = watch.clone();
        mux.request_stream(
            conn,
            bin::OP_SUBSCRIBE_BANK,
            bin::encode_u64(bank),
            Arc::new(move |bytes: Vec<u8>| {
                if let Ok(ev) = bin::decode_bank_event(&bytes) {
                    apply_watch.apply(&ev);
                }
            }),
            Box::new(move |res| watch.finish(res.map(|_| ()))),
        );
    }

    /// The single consuming network wait issued once the local watch is
    /// terminal: instant server-side (the bank is done) and it performs
    /// the same bank GC a poll-driven client would.
    fn net_wait(&self, bank: u64, timeout_ms: Option<u64>) -> Result<Vec<f32>, DqError> {
        match &*self.plane {
            Plane::Bin { mux, conn, .. } => {
                let bytes = Self::bin_call(
                    mux,
                    *conn,
                    bin::OP_WAIT_BANK,
                    bin::encode_wait_request(bank, timeout_ms),
                )?;
                bin::decode_fids(&bytes)
            }
            Plane::Json(rpc) => {
                let mut params = Value::obj().with("bank", bank);
                if let Some(ms) = timeout_ms {
                    params.set("timeout_ms", ms);
                }
                let resp = rpc.call("wait_bank", params)?;
                Ok(resp.req_f32_vec("fids")?)
            }
        }
    }
}

impl SessionOps for RemoteOps {
    fn submit(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<u64, DqError> {
        let req = SubmitRequest { client, config, pairs: pairs.to_vec() };
        match &*self.plane {
            Plane::Bin { mux, conn, features } => {
                let bytes =
                    Self::bin_call(mux, *conn, bin::OP_SUBMIT_BANK, bin::encode_submit_request(&req))?;
                let bank = bin::decode_submit_response(&bytes)?.bank;
                if features & bin::FEAT_PUSH != 0 {
                    self.subscribe(mux, *conn, bank, req.pairs.len());
                }
                Ok(bank)
            }
            Plane::Json(rpc) => {
                let resp = rpc.call("submit_bank", req.to_wire())?;
                Ok(SubmitResponse::from_wire(&resp)?.bank)
            }
        }
    }

    fn wait(&self, bank: u64, timeout: Option<Duration>) -> Result<Vec<f32>, DqError> {
        let timeout_ms = timeout.map(|t| t.as_millis() as u64);
        match (timeout, self.watch(bank)) {
            // Bounded wait on a subscribed bank: block on the locally
            // streamed events, touch the wire only once terminal.
            (Some(t), Some(watch)) => {
                if !watch.wait_terminal(t) {
                    return Err(DqError::Timeout(format!(
                        "bank {bank} not complete after {:?}",
                        t
                    )));
                }
                self.drop_watch(bank);
                self.net_wait(bank, timeout_ms)
            }
            // Unbounded wait: let the server block for us (the park
            // defers it), then retire the watch.
            _ => {
                let res = self.net_wait(bank, timeout_ms);
                if !matches!(res, Err(DqError::Timeout(_))) {
                    // terminal either way — a timed-out bank stays live
                    self.drop_watch(bank);
                }
                res
            }
        }
    }

    fn status(&self, bank: u64) -> Result<BankStatus, DqError> {
        if let Some(watch) = self.watch(bank) {
            return Ok(watch.status());
        }
        self.status_polls.fetch_add(1, Ordering::Relaxed);
        match &*self.plane {
            Plane::Bin { mux, conn, .. } => {
                let bytes = Self::bin_call(mux, *conn, bin::OP_BANK_STATUS, bin::encode_u64(bank))?;
                bin::decode_bank_status(&bytes)
            }
            Plane::Json(rpc) => {
                let resp = rpc.call("bank_status", Value::obj().with("bank", bank))?;
                proto::bank_status_from_wire(&resp)
            }
        }
    }

    fn cancel(&self, bank: u64) -> Result<usize, DqError> {
        let drained = match &*self.plane {
            Plane::Bin { mux, conn, .. } => {
                let bytes = Self::bin_call(mux, *conn, bin::OP_CANCEL_BANK, bin::encode_u64(bank))?;
                bin::decode_u64(&bytes)? as usize
            }
            Plane::Json(rpc) => {
                let resp = rpc.call("cancel_bank", Value::obj().with("bank", bank))?;
                resp.req_usize("drained")?
            }
        };
        self.drop_watch(bank);
        Ok(drained)
    }
}

/// A client connected to a remote manager; hands out typed
/// [`ClientSession`]s and implements [`CircuitExecutor`] itself so
/// training code is deployment-agnostic.
///
/// The connection is negotiated binary-first through
/// [`crate::net::rpc::dial_plane`]; [`RemoteClient::is_binary`] reports
/// which plane answered.
pub struct RemoteClient {
    plane: Arc<Plane>,
    client_id: u64,
    status_polls: Arc<AtomicU64>,
}

impl RemoteClient {
    /// Dial a manager (binary-first, JSON fallback) and allocate this
    /// connection's default client id.
    pub fn connect(manager_addr: &str) -> Result<RemoteClient, DqError> {
        let mux = Mux::new(MuxConfig::default());
        let plane = Arc::new(
            dial_plane(&mux, manager_addr, Duration::from_secs(5))
                .map_err(|e| DqError::Io(format!("connect manager: {e}")))?,
        );
        let client_id = Self::alloc_client(&plane)?;
        Ok(RemoteClient { plane, client_id, status_polls: Arc::new(AtomicU64::new(0)) })
    }

    fn alloc_client(plane: &Plane) -> Result<u64, DqError> {
        match plane {
            Plane::Bin { mux, conn, .. } => {
                bin::decode_u64(&mux.call(*conn, bin::OP_NEW_CLIENT, Vec::new())?)
            }
            Plane::Json(rpc) => Ok(rpc.call("new_client", Value::obj())?.req_u64("client")?),
        }
    }

    /// This connection's default client id (the manager's tenant key).
    pub fn client_id(&self) -> u64 {
        self.client_id
    }

    /// How many `bank_status` calls actually hit the wire across every
    /// session of this client. On a push-negotiated binary plane,
    /// subscribed banks answer `status`/`try_poll` locally — the mux
    /// reconnect suite pins this counter at zero.
    pub fn status_polls(&self) -> u64 {
        self.status_polls.load(Ordering::Relaxed)
    }

    /// Did the dial negotiate the binary plane (vs JSON fallback)?
    pub fn is_binary(&self) -> bool {
        self.plane.is_binary()
    }

    /// A typed session bound to a fresh tenant id. Multiple calls
    /// allocate fresh tenant ids from the manager.
    ///
    /// On the binary plane blocking `wait`s are deferred off the
    /// server's transport threads and `try_poll` answers from the push
    /// stream locally, so waits and polls through one `RemoteClient`
    /// overlap freely. JSON-plane calls on one connection still
    /// serialize — poll-then-wait (or a second connection) there.
    pub fn session(&self) -> Result<ClientSession, DqError> {
        let client = Self::alloc_client(&self.plane)?;
        Ok(ClientSession::new(
            Arc::new(RemoteOps::new(self.plane.clone(), self.status_polls.clone())),
            client,
        ))
    }

    /// Typed pool statistics: aggregate counters plus the live worker
    /// and queue-depth gauges. Works on either plane.
    pub fn stats(&self) -> Result<(ManagerStats, u64, u64), DqError> {
        match &*self.plane {
            Plane::Bin { mux, conn, .. } => {
                bin::decode_pool_stats(&mux.call(*conn, bin::OP_STATS, Vec::new())?)
            }
            Plane::Json(rpc) => {
                let v = rpc.call("stats", Value::obj())?;
                let stats = proto::manager_stats_from_wire(&v)?;
                Ok((stats, v.req_u64("workers")?, v.req_u64("queue")?))
            }
        }
    }

    /// Raw JSON stats envelope, kept for dashboards that scrape the
    /// wire shape. On a binary connection the envelope is re-synthesized
    /// locally from the typed stats.
    #[deprecated(since = "0.1.0", note = "use RemoteClient::stats (typed, plane-agnostic)")]
    pub fn manager_stats(&self) -> Result<Value, DqError> {
        match &*self.plane {
            Plane::Json(rpc) => rpc.call("stats", Value::obj()),
            Plane::Bin { .. } => {
                let (stats, workers, queue) = self.stats()?;
                Ok(proto::manager_stats_to_wire(&stats)
                    .with("workers", workers)
                    .with("queue", queue))
            }
        }
    }
}

impl CircuitExecutor for RemoteClient {
    fn execute_bank(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        let ops = RemoteOps::new(self.plane.clone(), self.status_polls.clone());
        let bank = ops.submit(self.client_id, *config, pairs)?;
        ops.wait(bank, None)
    }

    fn describe(&self) -> String {
        format!("remote client #{}", self.client_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ManagerConfig;
    use crate::model::exec::QsimExecutor;
    use crate::util::Rng;
    use crate::worker::{WorkerHandle, WorkerOptions};

    /// Full TCP round trip: manager server, two real worker processes
    /// (threads), remote client — the paper's deployment in miniature.
    #[test]
    fn tcp_cluster_end_to_end() {
        let manager = Manager::new(ManagerConfig {
            heartbeat_period: 0.2,
            ..Default::default()
        });
        let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();

        let mk_worker = |mq: usize| {
            WorkerHandle::start(
                &addr,
                WorkerOptions {
                    max_qubits: mq,
                    artifact_dir: "/nonexistent".into(), // qsim backend
                    heartbeat_period: 0.1,
                    listen: "127.0.0.1:0".to_string(),
                    threads: 2,
                },
            )
            .unwrap()
        };
        let mut w1 = mk_worker(5);
        let mut w2 = mk_worker(10);

        let client = RemoteClient::connect(&addr).unwrap();
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let mut rng = Rng::new(2);
        let pairs: Vec<CircuitPair> = (0..12)
            .map(|_| {
                (
                    (0..cfg.n_params()).map(|_| rng.f32()).collect(),
                    (0..cfg.n_features()).map(|_| rng.f32()).collect(),
                )
            })
            .collect();
        let fids = client.execute_bank(&cfg, &pairs).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());

        // the typed session API over the same connection
        let session = client.session().unwrap();
        let handle = session.submit(cfg, &pairs).unwrap();
        assert_eq!(handle.total(), 12);
        let fids2 = handle.wait().unwrap();
        assert_eq!(fids2, fids);

        // client↔manager negotiated the binary plane against the
        // dual-codec server
        assert!(client.is_binary());
        let (stats, workers, _queue) = client.stats().unwrap();
        assert_eq!(stats.completed, 24);
        assert_eq!(workers, 2);
        // the deprecated JSON-shaped envelope still answers
        #[allow(deprecated)]
        let raw = client.manager_stats().unwrap();
        assert_eq!(raw.req_u64("completed").unwrap(), 24);

        w1.stop();
        w2.stop();
        manager.shutdown();
    }

    /// The same round trip against a JSON-only server: the client's
    /// binary handshake fails, it falls back, and every op still works.
    #[test]
    fn json_fallback_cluster_end_to_end() {
        let manager = Manager::new(ManagerConfig { heartbeat_period: 0.2, ..Default::default() });
        let server = serve_pool_json(manager.clone(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let mut w = WorkerHandle::start(
            &addr,
            WorkerOptions {
                max_qubits: 5,
                artifact_dir: "/nonexistent".into(),
                heartbeat_period: 0.1,
                listen: "127.0.0.1:0".to_string(),
                threads: 1,
            },
        )
        .unwrap();

        let client = RemoteClient::connect(&addr).unwrap();
        assert!(!client.is_binary());
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs: Vec<CircuitPair> = vec![(vec![0.3; 4], vec![0.6; 4]); 6];
        let session = client.session().unwrap();
        let fids = session.execute(cfg, &pairs).unwrap();
        assert_eq!(fids.len(), 6);
        let (stats, workers, _queue) = client.stats().unwrap();
        assert_eq!(stats.completed, 6);
        assert_eq!(workers, 1);

        w.stop();
        manager.shutdown();
    }

    /// A [`ShardManager`] behind the same server: remote clients and
    /// workers cannot tell how many shards answer them, and the striped
    /// routing completes banks end to end.
    #[test]
    fn sharded_pool_serves_tcp() {
        use crate::coordinator::ShardConfig;
        let sm = ShardManager::new(ShardConfig {
            shards: 2,
            manager: ManagerConfig { heartbeat_period: 0.2, ..Default::default() },
            ..Default::default()
        });
        let server = serve_pool(sm.clone(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let mk_worker = || {
            WorkerHandle::start(
                &addr,
                WorkerOptions {
                    max_qubits: 5,
                    artifact_dir: "/nonexistent".into(),
                    heartbeat_period: 0.1,
                    listen: "127.0.0.1:0".to_string(),
                    threads: 1,
                },
            )
            .unwrap()
        };
        let mut w1 = mk_worker();
        let mut w2 = mk_worker();

        let client = RemoteClient::connect(&addr).unwrap();
        assert!(client.is_binary());
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs: Vec<CircuitPair> = vec![(vec![0.1; 4], vec![0.9; 4]); 10];
        // two sessions land on different shards (round-robin client ids)
        for _ in 0..2 {
            let session = client.session().unwrap();
            let fids = session.execute(cfg, &pairs).unwrap();
            assert_eq!(fids.len(), 10);
        }
        let (stats, workers, _queue) = client.stats().unwrap();
        assert_eq!(stats.completed, 20);
        assert_eq!(workers, 2);

        w1.stop();
        w2.stop();
        sm.shutdown();
    }

    /// Kill a worker mid-run: heartbeats stop, the manager evicts it, and
    /// the system completes on the survivor (fault tolerance).
    #[test]
    fn worker_failure_is_tolerated() {
        let manager = Manager::new(ManagerConfig {
            heartbeat_period: 0.1,
            max_batch: 2,
            ..Default::default()
        });
        let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();

        let mut w1 = WorkerHandle::start(
            &addr,
            WorkerOptions {
                max_qubits: 5,
                artifact_dir: "/nonexistent".into(),
                heartbeat_period: 0.05,
                listen: "127.0.0.1:0".to_string(),
                threads: 1,
            },
        )
        .unwrap();
        // stop w1's heartbeats immediately; it will be evicted
        w1.stop();

        let survivor = WorkerHandle::start(
            &addr,
            WorkerOptions {
                max_qubits: 5,
                artifact_dir: "/nonexistent".into(),
                heartbeat_period: 0.05,
                listen: "127.0.0.1:0".to_string(),
                threads: 1,
            },
        )
        .unwrap();

        let client = RemoteClient::connect(&addr).unwrap();
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs: Vec<CircuitPair> = vec![(vec![0.2; 4], vec![0.4; 4]); 8];
        let fids = client.execute_bank(&cfg, &pairs).unwrap();
        assert_eq!(fids.len(), 8);
        // eventually only the survivor remains registered
        std::thread::sleep(Duration::from_millis(600));
        assert_eq!(manager.worker_count(), 1);

        drop(survivor);
        manager.shutdown();
    }

    /// A typed error raised manager-side arrives as the same variant on
    /// the remote side (the wire round trip the taxonomy promises).
    #[test]
    fn unschedulable_error_round_trips_over_tcp() {
        let manager = Manager::new(ManagerConfig::default());
        let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let mut w = WorkerHandle::start(
            &addr,
            WorkerOptions {
                max_qubits: 5,
                artifact_dir: "/nonexistent".into(),
                heartbeat_period: 0.5,
                listen: "127.0.0.1:0".to_string(),
                threads: 1,
            },
        )
        .unwrap();
        let client = RemoteClient::connect(&addr).unwrap();
        let session = client.session().unwrap();
        let cfg = QuClassiConfig::new(9, 1).unwrap(); // needs 9 > 5
        let pairs: Vec<CircuitPair> = vec![(vec![0.1; 8], vec![0.1; 8]); 2];
        let err = session.submit(cfg, &pairs).unwrap().wait().unwrap_err();
        assert!(matches!(err, DqError::Unschedulable(_)), "{err}");
        w.stop();
        manager.shutdown();
    }
}
