//! Distributed deployment over TCP: manager RPC server, manager→worker
//! channel, and the remote client.
//!
//! Message flow (all framed JSON, `net::rpc` envelope):
//!
//! ```text
//! worker  -> manager : register {max_qubits, addr, cru, threads} -> {worker_id}
//! worker  -> manager : heartbeat {worker_id, cru}
//! client  -> manager : submit_bank {client, qubits, layers, circuits} -> {bank}
//! client  -> manager : wait_bank {bank} -> {fids}
//! manager -> worker  : execute {circuits} -> {fids}
//! ```

use std::sync::Arc;
use std::time::Duration;

use crate::circuit::QuClassiConfig;
use crate::coordinator::job::CircuitJob;
use crate::coordinator::{Manager, WorkerChannel};
use crate::model::exec::{CircuitExecutor, CircuitPair};
use crate::net::{RpcClient, RpcServer};
use crate::wire::Value;

/// Manager→worker channel over RPC.
struct RpcWorkerChannel {
    client: RpcClient,
}

impl WorkerChannel for RpcWorkerChannel {
    fn execute(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, String> {
        let circuits: Vec<Value> = pairs
            .iter()
            .enumerate()
            .map(|(i, (thetas, data))| {
                CircuitJob {
                    id: i as u64,
                    client: 0,
                    bank: 0,
                    index: i,
                    config: *config,
                    thetas: thetas.clone(),
                    data: data.clone(),
                }
                .to_wire()
            })
            .collect();
        let resp = self
            .client
            .call("execute", Value::obj().with("circuits", circuits))
            .map_err(|e| format!("worker rpc: {e}"))?;
        resp.req_f32_vec("fids")
    }
}

/// Expose a [`Manager`] on a TCP address. Returns the server handle
/// (drop to stop accepting).
pub fn serve_manager(manager: Manager, listen: &str) -> std::io::Result<RpcServer> {
    let handler = move |op: &str, params: &Value| -> Result<Value, String> {
        match op {
            "register" => {
                let max_qubits = params.req_usize("max_qubits")?;
                let addr = params.req_str("addr")?.to_string();
                let cru = params.req_f64("cru").unwrap_or(0.0);
                // Optional thread budget (older workers omit it): sizes
                // dispatch batches to the worker's real parallelism.
                let threads = params.get("threads").and_then(Value::as_usize).unwrap_or(1);
                let rpc = RpcClient::connect(addr.as_str(), Duration::from_secs(5))
                    .map_err(|e| format!("dial worker back: {e}"))?;
                let id = manager.register_worker_full(
                    max_qubits,
                    cru,
                    0.0,
                    threads,
                    Arc::new(RpcWorkerChannel { client: rpc }),
                );
                Ok(Value::obj().with("worker_id", id))
            }
            "heartbeat" => {
                let id = params.req_u64("worker_id")?;
                let cru = params.req_f64("cru").unwrap_or(0.0);
                manager.heartbeat(id, cru)?;
                Ok(Value::obj())
            }
            "new_client" => Ok(Value::obj().with("client", manager.new_client())),
            "submit_bank" => {
                let client = params.req_u64("client")?;
                let config =
                    QuClassiConfig::new(params.req_usize("qubits")?, params.req_usize("layers")?)?;
                let circuits = params.req_arr("circuits")?;
                let mut pairs = Vec::with_capacity(circuits.len());
                for c in circuits {
                    let thetas = c.req_f32_vec("thetas")?;
                    let data = c.req_f32_vec("data")?;
                    pairs.push((thetas, data));
                }
                let bank = manager.submit_bank(client, config, &pairs)?;
                Ok(Value::obj().with("bank", bank))
            }
            "wait_bank" => {
                let bank = params.req_u64("bank")?;
                let fids = manager.wait_bank(bank)?;
                Ok(Value::obj().with("fids", fids.as_slice()))
            }
            "stats" => {
                let s = manager.stats();
                Ok(Value::obj()
                    .with("submitted", s.submitted)
                    .with("completed", s.completed)
                    .with("dispatches", s.dispatches)
                    .with("requeues", s.requeues)
                    .with("evictions", s.evictions)
                    .with("workers", manager.worker_count())
                    .with("queue", manager.queue_len()))
            }
            other => Err(format!("manager: unknown op '{other}'")),
        }
    };
    RpcServer::serve(listen, Arc::new(handler))
}

/// A client connected to a remote manager; implements
/// [`CircuitExecutor`] so training code is deployment-agnostic.
pub struct RemoteClient {
    rpc: RpcClient,
    client_id: u64,
}

impl RemoteClient {
    pub fn connect(manager_addr: &str) -> Result<RemoteClient, String> {
        let rpc = RpcClient::connect(manager_addr, Duration::from_secs(5))
            .map_err(|e| format!("connect manager: {e}"))?;
        let resp = rpc.call("new_client", Value::obj()).map_err(|e| e.to_string())?;
        let client_id = resp.req_u64("client")?;
        Ok(RemoteClient { rpc, client_id })
    }

    pub fn client_id(&self) -> u64 {
        self.client_id
    }

    pub fn manager_stats(&self) -> Result<Value, String> {
        self.rpc.call("stats", Value::obj()).map_err(|e| e.to_string())
    }
}

impl CircuitExecutor for RemoteClient {
    fn execute_bank(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, String> {
        let circuits: Vec<Value> = pairs
            .iter()
            .map(|(t, d)| Value::obj().with("thetas", t.as_slice()).with("data", d.as_slice()))
            .collect();
        let resp = self
            .rpc
            .call(
                "submit_bank",
                Value::obj()
                    .with("client", self.client_id)
                    .with("qubits", config.qubits)
                    .with("layers", config.layers)
                    .with("circuits", circuits),
            )
            .map_err(|e| e.to_string())?;
        let bank = resp.req_u64("bank")?;
        let resp = self
            .rpc
            .call("wait_bank", Value::obj().with("bank", bank))
            .map_err(|e| e.to_string())?;
        resp.req_f32_vec("fids")
    }

    fn describe(&self) -> String {
        format!("remote client #{}", self.client_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ManagerConfig;
    use crate::model::exec::QsimExecutor;
    use crate::util::Rng;
    use crate::worker::{WorkerHandle, WorkerOptions};

    /// Full TCP round trip: manager server, two real worker processes
    /// (threads), remote client — the paper's deployment in miniature.
    #[test]
    fn tcp_cluster_end_to_end() {
        let manager = Manager::new(ManagerConfig {
            heartbeat_period: 0.2,
            ..Default::default()
        });
        let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();

        let mk_worker = |mq: usize| {
            WorkerHandle::start(
                &addr,
                WorkerOptions {
                    max_qubits: mq,
                    artifact_dir: "/nonexistent".into(), // qsim backend
                    heartbeat_period: 0.1,
                    listen: "127.0.0.1:0".to_string(),
                    threads: 2,
                },
            )
            .unwrap()
        };
        let mut w1 = mk_worker(5);
        let mut w2 = mk_worker(10);

        let client = RemoteClient::connect(&addr).unwrap();
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let mut rng = Rng::new(2);
        let pairs: Vec<CircuitPair> = (0..12)
            .map(|_| {
                (
                    (0..cfg.n_params()).map(|_| rng.f32()).collect(),
                    (0..cfg.n_features()).map(|_| rng.f32()).collect(),
                )
            })
            .collect();
        let fids = client.execute_bank(&cfg, &pairs).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());

        let stats = client.manager_stats().unwrap();
        assert_eq!(stats.req_u64("completed").unwrap(), 12);
        assert_eq!(stats.req_u64("workers").unwrap(), 2);

        w1.stop();
        w2.stop();
        manager.shutdown();
    }

    /// Kill a worker mid-run: heartbeats stop, the manager evicts it, and
    /// the system completes on the survivor (fault tolerance).
    #[test]
    fn worker_failure_is_tolerated() {
        let manager = Manager::new(ManagerConfig {
            heartbeat_period: 0.1,
            max_batch: 2,
            ..Default::default()
        });
        let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();

        let mut w1 = WorkerHandle::start(
            &addr,
            WorkerOptions {
                max_qubits: 5,
                artifact_dir: "/nonexistent".into(),
                heartbeat_period: 0.05,
                listen: "127.0.0.1:0".to_string(),
                threads: 1,
            },
        )
        .unwrap();
        // stop w1's heartbeats immediately; it will be evicted
        w1.stop();

        let survivor = WorkerHandle::start(
            &addr,
            WorkerOptions {
                max_qubits: 5,
                artifact_dir: "/nonexistent".into(),
                heartbeat_period: 0.05,
                listen: "127.0.0.1:0".to_string(),
                threads: 1,
            },
        )
        .unwrap();

        let client = RemoteClient::connect(&addr).unwrap();
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs: Vec<CircuitPair> = vec![(vec![0.2; 4], vec![0.4; 4]); 8];
        let fids = client.execute_bank(&cfg, &pairs).unwrap();
        assert_eq!(fids.len(), 8);
        // eventually only the survivor remains registered
        std::thread::sleep(Duration::from_millis(600));
        assert_eq!(manager.worker_count(), 1);

        drop(survivor);
        manager.shutdown();
    }
}
