//! Distributed deployment over TCP: manager RPC server, manager→worker
//! channel, and the remote client.
//!
//! Message flow (all framed JSON, `net::rpc` envelope; client↔manager
//! payloads are the typed pairs in [`super::proto`]):
//!
//! ```text
//! worker  -> manager : register {max_qubits, addr, cru, threads} -> {worker_id}
//! worker  -> manager : heartbeat {worker_id, cru}
//! client  -> manager : submit_bank <SubmitRequest>     -> <SubmitResponse>
//! client  -> manager : wait_bank {bank, timeout_ms?}   -> {fids}
//! client  -> manager : bank_status {bank}              -> <BankStatus>
//! client  -> manager : cancel_bank {bank}              -> {drained}
//! manager -> worker  : execute {circuits}              -> {fids}
//! ```
//!
//! Errors round-trip typed: a bank the manager fails with
//! `DqError::Unschedulable` (or a client cancels to `Cancelled`) surfaces
//! as that same variant on the remote side.
//!
//! Trust model: the protocol is *cooperative* — client ids, bank ids,
//! and worker registration are unauthenticated sequential handles, so
//! any peer that can reach the manager can wait on, poll, or cancel any
//! bank. Deploy on a trusted network segment (DESIGN.md §12).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::proto::{self, SubmitRequest, SubmitResponse};
use crate::circuit::QuClassiConfig;
use crate::coordinator::job::CircuitJob;
use crate::coordinator::session::{ClientSession, SessionOps};
use crate::coordinator::{BankStatus, Manager, WorkerChannel, WorkerProfile};
use crate::error::DqError;
use crate::model::exec::{CircuitExecutor, CircuitPair};
use crate::net::{Mux, MuxConfig, RpcClient, RpcServer};
use crate::wire::{bin, Value};

/// Build the per-dispatch job list a worker executes (ids are
/// per-dispatch ordinals; the manager's bookkeeping stays local).
fn dispatch_jobs(config: &QuClassiConfig, pairs: &[CircuitPair]) -> Vec<CircuitJob> {
    pairs
        .iter()
        .enumerate()
        .map(|(i, (thetas, data))| CircuitJob {
            id: i as u64,
            client: 0,
            bank: 0,
            index: i,
            config: *config,
            thetas: thetas.clone(),
            data: data.clone(),
        })
        .collect()
}

/// Manager→worker channel over JSON RPC — the fallback plane. Executed
/// on the worker's outbox dispatcher thread (DESIGN.md §13): the
/// blocking RPC round trip ties up only this worker's outbox, so a slow
/// or unreachable remote worker never delays dispatch to its siblings.
///
/// The connection self-heals: a connection-level failure drops the
/// socket and redials under capped backoff + jitter (up to 3 attempts
/// per execute), so a transient network blip or worker restart is not
/// immediately escalated into a lost worker.
struct RpcWorkerChannel {
    addr: String,
    client: Mutex<Option<RpcClient>>,
}

impl RpcWorkerChannel {
    fn new(addr: String, client: RpcClient) -> RpcWorkerChannel {
        RpcWorkerChannel { addr, client: Mutex::new(Some(client)) }
    }
}

impl WorkerChannel for RpcWorkerChannel {
    fn execute(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        let circuits: Vec<Value> =
            dispatch_jobs(config, pairs).iter().map(CircuitJob::to_wire).collect();
        let params = Value::obj().with("circuits", circuits);
        let mut last = DqError::Io(format!("worker {} unreachable", self.addr));
        for _ in 0..3 {
            let mut guard = self.client.lock().expect("rpc channel poisoned");
            if guard.is_none() {
                // RpcClient::connect retries under capped backoff +
                // jitter for its whole budget before giving up.
                match RpcClient::connect(self.addr.as_str(), Duration::from_secs(2)) {
                    Ok(c) => *guard = Some(c),
                    Err(e) => {
                        last = e;
                        continue;
                    }
                }
            }
            let client = guard.as_ref().expect("client ensured above");
            match client.call("execute", params.clone()) {
                Ok(resp) => return Ok(resp.req_f32_vec("fids")?),
                Err(DqError::Io(msg)) => {
                    // Connection-level failure: drop the socket, redial.
                    *guard = None;
                    last = DqError::Io(msg);
                }
                Err(e) => return Err(e),
            }
        }
        Err(last)
    }
}

/// Manager→worker channel over the multiplexed binary plane. Async: the
/// outbox dispatcher enqueues the request and returns immediately; the
/// completion arrives on the mux transport threads. A torn-down
/// connection (idle timeout, peer death) fails in flight and future
/// requests with [`DqError::WorkerLost`], feeding the existing
/// requeue/eviction path.
pub struct MuxWorkerChannel {
    mux: Arc<Mux>,
    conn: u64,
}

impl MuxWorkerChannel {
    pub fn new(mux: Arc<Mux>, conn: u64) -> MuxWorkerChannel {
        MuxWorkerChannel { mux, conn }
    }
}

impl WorkerChannel for MuxWorkerChannel {
    fn execute(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        let payload = bin::encode_jobs(&dispatch_jobs(config, pairs));
        let bytes = self.mux.call(self.conn, bin::OP_EXECUTE, payload)?;
        bin::decode_fids(&bytes)
    }

    fn is_async(&self) -> bool {
        true
    }

    fn execute_async(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
        done: Box<dyn FnOnce(Result<Vec<f32>, DqError>) + Send + 'static>,
    ) {
        let payload = bin::encode_jobs(&dispatch_jobs(config, pairs));
        self.mux.request(
            self.conn,
            bin::OP_EXECUTE,
            payload,
            Box::new(move |res| done(res.and_then(|bytes| bin::decode_fids(&bytes)))),
        );
    }
}

/// Expose a [`Manager`] on a TCP address. Returns the server handle
/// (drop to stop accepting).
///
/// Worker dial-back negotiates the binary plane first: one shared
/// [`Mux`] (created lazily on the first registration) multiplexes every
/// worker that speaks it; a worker whose handshake fails — an old
/// JSON-only build — gets the classic [`RpcClient`] channel instead.
pub fn serve_manager(manager: Manager, listen: &str) -> std::io::Result<RpcServer> {
    let mux: Mutex<Option<Arc<Mux>>> = Mutex::new(None);
    let handler = move |op: &str, params: &Value| -> Result<Value, DqError> {
        match op {
            "register" => {
                let max_qubits = params.req_usize("max_qubits")?;
                let addr = params.req_str("addr")?.to_string();
                let cru = params.req_f64("cru").unwrap_or(0.0);
                // Optional thread budget (older workers omit it): sizes
                // dispatch batches to the worker's real parallelism.
                let threads = params.get("threads").and_then(Value::as_usize).unwrap_or(1);
                let m = {
                    let mut slot = mux.lock().expect("mux slot poisoned");
                    slot.get_or_insert_with(|| Mux::new(MuxConfig::default())).clone()
                };
                let channel: Arc<dyn WorkerChannel> = match m.connect(addr.as_str()) {
                    Ok(conn) => Arc::new(MuxWorkerChannel::new(m, conn.id)),
                    Err(e) => {
                        // JSON fallback: the worker predates the binary
                        // plane (or refused the handshake).
                        crate::log_info!(
                            "cluster",
                            "worker at {addr} falls back to JSON ({e})"
                        );
                        let rpc = RpcClient::connect(addr.as_str(), Duration::from_secs(5))
                            .map_err(|e| DqError::Io(format!("dial worker back: {e}")))?;
                        Arc::new(RpcWorkerChannel::new(addr, rpc))
                    }
                };
                let id = manager
                    .register(WorkerProfile::new(max_qubits).cru(cru).threads(threads), channel);
                Ok(Value::obj().with("worker_id", id))
            }
            "heartbeat" => {
                let id = params.req_u64("worker_id")?;
                let cru = params.req_f64("cru").unwrap_or(0.0);
                manager.heartbeat(id, cru)?;
                Ok(Value::obj())
            }
            "new_client" => Ok(Value::obj().with("client", manager.new_client())),
            "submit_bank" => {
                let req = SubmitRequest::from_wire(params)?;
                let bank = manager.submit_bank(req.client, req.config, &req.pairs)?;
                Ok(SubmitResponse { bank, total: req.pairs.len() }.to_wire())
            }
            "wait_bank" => {
                let bank = params.req_u64("bank")?;
                let fids = match params.get("timeout_ms").and_then(Value::as_u64) {
                    Some(ms) => manager.wait_bank_timeout(bank, Duration::from_millis(ms))?,
                    None => manager.wait_bank(bank)?,
                };
                Ok(Value::obj().with("fids", fids.as_slice()))
            }
            "bank_status" => {
                let bank = params.req_u64("bank")?;
                let status = manager.bank_status(bank).ok_or_else(|| {
                    if manager.bank_cancelled(bank) {
                        DqError::Cancelled(format!("bank {bank} cancelled"))
                    } else {
                        DqError::Protocol(format!("unknown bank {bank}"))
                    }
                })?;
                Ok(proto::bank_status_to_wire(&status))
            }
            "cancel_bank" => {
                let bank = params.req_u64("bank")?;
                Ok(Value::obj().with("drained", manager.cancel_bank(bank)))
            }
            "stats" => {
                // The counters (incl. per-tenant wait histograms and
                // steal/retention fields) serialize through the shared
                // proto codec; the live pool/queue gauges ride on top.
                Ok(proto::manager_stats_to_wire(&manager.stats())
                    .with("workers", manager.worker_count())
                    .with("queue", manager.queue_len()))
            }
            other => Err(DqError::Protocol(format!("manager: unknown op '{other}'"))),
        }
    };
    RpcServer::serve(listen, Arc::new(handler))
}

/// [`SessionOps`] over the RPC connection: the transport behind remote
/// [`ClientSession`]s.
struct RemoteOps {
    rpc: Arc<RpcClient>,
}

impl SessionOps for RemoteOps {
    fn submit(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<u64, DqError> {
        let req = SubmitRequest { client, config, pairs: pairs.to_vec() };
        let resp = self.rpc.call("submit_bank", req.to_wire())?;
        Ok(SubmitResponse::from_wire(&resp)?.bank)
    }

    fn wait(&self, bank: u64, timeout: Option<Duration>) -> Result<Vec<f32>, DqError> {
        let mut params = Value::obj().with("bank", bank);
        if let Some(t) = timeout {
            params.set("timeout_ms", t.as_millis() as u64);
        }
        let resp = self.rpc.call("wait_bank", params)?;
        Ok(resp.req_f32_vec("fids")?)
    }

    fn status(&self, bank: u64) -> Result<BankStatus, DqError> {
        let resp = self.rpc.call("bank_status", Value::obj().with("bank", bank))?;
        proto::bank_status_from_wire(&resp)
    }

    fn cancel(&self, bank: u64) -> Result<usize, DqError> {
        let resp = self.rpc.call("cancel_bank", Value::obj().with("bank", bank))?;
        Ok(resp.req_usize("drained")?)
    }
}

/// A client connected to a remote manager; hands out typed
/// [`ClientSession`]s and implements [`CircuitExecutor`] itself so
/// training code is deployment-agnostic.
pub struct RemoteClient {
    rpc: Arc<RpcClient>,
    client_id: u64,
}

impl RemoteClient {
    pub fn connect(manager_addr: &str) -> Result<RemoteClient, DqError> {
        let rpc = RpcClient::connect(manager_addr, Duration::from_secs(5))
            .map_err(|e| DqError::Io(format!("connect manager: {e}")))?;
        let resp = rpc.call("new_client", Value::obj())?;
        let client_id = resp.req_u64("client")?;
        Ok(RemoteClient { rpc: Arc::new(rpc), client_id })
    }

    pub fn client_id(&self) -> u64 {
        self.client_id
    }

    /// A typed session bound to this connection's client id. Multiple
    /// calls allocate fresh tenant ids from the manager.
    ///
    /// Note: calls on one connection serialize; a long blocking `wait`
    /// delays a concurrent `try_poll` issued through the same
    /// `RemoteClient`. Poll-then-wait (or a second connection) if you
    /// need overlap.
    pub fn session(&self) -> Result<ClientSession, DqError> {
        let resp = self.rpc.call("new_client", Value::obj())?;
        let client = resp.req_u64("client")?;
        Ok(ClientSession::new(Arc::new(RemoteOps { rpc: self.rpc.clone() }), client))
    }

    pub fn manager_stats(&self) -> Result<Value, DqError> {
        self.rpc.call("stats", Value::obj())
    }
}

impl CircuitExecutor for RemoteClient {
    fn execute_bank(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        let ops = RemoteOps { rpc: self.rpc.clone() };
        let bank = ops.submit(self.client_id, *config, pairs)?;
        ops.wait(bank, None)
    }

    fn describe(&self) -> String {
        format!("remote client #{}", self.client_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ManagerConfig;
    use crate::model::exec::QsimExecutor;
    use crate::util::Rng;
    use crate::worker::{WorkerHandle, WorkerOptions};

    /// Full TCP round trip: manager server, two real worker processes
    /// (threads), remote client — the paper's deployment in miniature.
    #[test]
    fn tcp_cluster_end_to_end() {
        let manager = Manager::new(ManagerConfig {
            heartbeat_period: 0.2,
            ..Default::default()
        });
        let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();

        let mk_worker = |mq: usize| {
            WorkerHandle::start(
                &addr,
                WorkerOptions {
                    max_qubits: mq,
                    artifact_dir: "/nonexistent".into(), // qsim backend
                    heartbeat_period: 0.1,
                    listen: "127.0.0.1:0".to_string(),
                    threads: 2,
                },
            )
            .unwrap()
        };
        let mut w1 = mk_worker(5);
        let mut w2 = mk_worker(10);

        let client = RemoteClient::connect(&addr).unwrap();
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let mut rng = Rng::new(2);
        let pairs: Vec<CircuitPair> = (0..12)
            .map(|_| {
                (
                    (0..cfg.n_params()).map(|_| rng.f32()).collect(),
                    (0..cfg.n_features()).map(|_| rng.f32()).collect(),
                )
            })
            .collect();
        let fids = client.execute_bank(&cfg, &pairs).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());

        // the typed session API over the same connection
        let session = client.session().unwrap();
        let handle = session.submit(cfg, &pairs).unwrap();
        assert_eq!(handle.total(), 12);
        let fids2 = handle.wait().unwrap();
        assert_eq!(fids2, fids);

        let stats = client.manager_stats().unwrap();
        assert_eq!(stats.req_u64("completed").unwrap(), 24);
        assert_eq!(stats.req_u64("workers").unwrap(), 2);

        w1.stop();
        w2.stop();
        manager.shutdown();
    }

    /// Kill a worker mid-run: heartbeats stop, the manager evicts it, and
    /// the system completes on the survivor (fault tolerance).
    #[test]
    fn worker_failure_is_tolerated() {
        let manager = Manager::new(ManagerConfig {
            heartbeat_period: 0.1,
            max_batch: 2,
            ..Default::default()
        });
        let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();

        let mut w1 = WorkerHandle::start(
            &addr,
            WorkerOptions {
                max_qubits: 5,
                artifact_dir: "/nonexistent".into(),
                heartbeat_period: 0.05,
                listen: "127.0.0.1:0".to_string(),
                threads: 1,
            },
        )
        .unwrap();
        // stop w1's heartbeats immediately; it will be evicted
        w1.stop();

        let survivor = WorkerHandle::start(
            &addr,
            WorkerOptions {
                max_qubits: 5,
                artifact_dir: "/nonexistent".into(),
                heartbeat_period: 0.05,
                listen: "127.0.0.1:0".to_string(),
                threads: 1,
            },
        )
        .unwrap();

        let client = RemoteClient::connect(&addr).unwrap();
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs: Vec<CircuitPair> = vec![(vec![0.2; 4], vec![0.4; 4]); 8];
        let fids = client.execute_bank(&cfg, &pairs).unwrap();
        assert_eq!(fids.len(), 8);
        // eventually only the survivor remains registered
        std::thread::sleep(Duration::from_millis(600));
        assert_eq!(manager.worker_count(), 1);

        drop(survivor);
        manager.shutdown();
    }

    /// A typed error raised manager-side arrives as the same variant on
    /// the remote side (the wire round trip the taxonomy promises).
    #[test]
    fn unschedulable_error_round_trips_over_tcp() {
        let manager = Manager::new(ManagerConfig::default());
        let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let mut w = WorkerHandle::start(
            &addr,
            WorkerOptions {
                max_qubits: 5,
                artifact_dir: "/nonexistent".into(),
                heartbeat_period: 0.5,
                listen: "127.0.0.1:0".to_string(),
                threads: 1,
            },
        )
        .unwrap();
        let client = RemoteClient::connect(&addr).unwrap();
        let session = client.session().unwrap();
        let cfg = QuClassiConfig::new(9, 1).unwrap(); // needs 9 > 5
        let pairs: Vec<CircuitPair> = vec![(vec![0.1; 8], vec![0.1; 8]); 2];
        let err = session.submit(cfg, &pairs).unwrap().wait().unwrap_err();
        assert!(matches!(err, DqError::Unschedulable(_)), "{err}");
        w.stop();
        manager.shutdown();
    }
}
