//! [`ClusterClient`] — one client surface over every deployment shape.
//!
//! Before this trait, each backend grew its own entry points
//! (`Manager::session`, `InProcCluster::new_client`,
//! `RemoteClient::manager_stats`, …) and code written against one could
//! not run against another. `ClusterClient` unifies them: a training
//! loop, a dashboard, or the principal federation layer takes
//! `&dyn ClusterClient` (or `Arc<dyn ClusterClient>`) and works against
//! a local [`Manager`], a sharded [`ShardManager`], an in-process
//! cluster, a remote TCP manager, or a principal federating all of the
//! above. See DESIGN.md §18 for the migration table from the deprecated
//! per-backend constructors.

use std::sync::Arc;

use super::inproc::InProcCluster;
use super::tcp::RemoteClient;
use crate::coordinator::{
    ClientSession, Manager, ManagerStats, ShardManager, WorkerChannel, WorkerId, WorkerProfile,
};
use crate::error::DqError;

/// The unified cluster surface: sessions in, workers in, stats out.
///
/// Every backend keeps its richer inherent API (striping controls,
/// recovery, plane introspection); this trait is the portable core that
/// all of them share. Operations a backend cannot perform return a
/// typed [`DqError`] instead of being absent — e.g. worker registration
/// through a [`RemoteClient`] (workers register by dialing the manager
/// themselves), so callers handle the refusal uniformly.
pub trait ClusterClient: Send + Sync {
    /// A typed session for a fresh tenant.
    fn session(&self) -> Result<ClientSession, DqError>;

    /// Register a worker channel with the pool; returns the worker id.
    fn register(
        &self,
        profile: WorkerProfile,
        channel: Arc<dyn WorkerChannel>,
    ) -> Result<WorkerId, DqError>;

    /// Aggregate pool counters.
    fn stats(&self) -> Result<ManagerStats, DqError>;

    /// Live worker count (scheduling-capacity gauge; the principal uses
    /// it to rebalance registrations across agents).
    fn worker_count(&self) -> usize;

    /// Stop the backend's threads. A no-op for connection handles whose
    /// server is owned elsewhere (e.g. [`RemoteClient`]).
    fn shutdown(&self);

    /// Human-readable backend description.
    fn describe(&self) -> String;
}

impl ClusterClient for Manager {
    fn session(&self) -> Result<ClientSession, DqError> {
        Ok(Manager::session(self))
    }

    fn register(
        &self,
        profile: WorkerProfile,
        channel: Arc<dyn WorkerChannel>,
    ) -> Result<WorkerId, DqError> {
        Ok(Manager::register(self, profile, channel))
    }

    fn stats(&self) -> Result<ManagerStats, DqError> {
        Ok(Manager::stats(self))
    }

    fn worker_count(&self) -> usize {
        Manager::worker_count(self)
    }

    fn shutdown(&self) {
        Manager::shutdown(self)
    }

    fn describe(&self) -> String {
        format!("co-manager ({} workers)", Manager::worker_count(self))
    }
}

impl ClusterClient for ShardManager {
    fn session(&self) -> Result<ClientSession, DqError> {
        Ok(ShardManager::session(self))
    }

    fn register(
        &self,
        profile: WorkerProfile,
        channel: Arc<dyn WorkerChannel>,
    ) -> Result<WorkerId, DqError> {
        Ok(ShardManager::register(self, profile, channel))
    }

    fn stats(&self) -> Result<ManagerStats, DqError> {
        Ok(ShardManager::stats(self))
    }

    fn worker_count(&self) -> usize {
        ShardManager::worker_count(self)
    }

    fn shutdown(&self) {
        ShardManager::shutdown(self)
    }

    fn describe(&self) -> String {
        format!(
            "sharded co-manager ({} shards, {} workers)",
            ShardManager::shards(self),
            ShardManager::worker_count(self)
        )
    }
}

impl ClusterClient for InProcCluster {
    fn session(&self) -> Result<ClientSession, DqError> {
        Ok(InProcCluster::session(self))
    }

    fn register(
        &self,
        profile: WorkerProfile,
        channel: Arc<dyn WorkerChannel>,
    ) -> Result<WorkerId, DqError> {
        Ok(self.manager.register(profile, channel))
    }

    fn stats(&self) -> Result<ManagerStats, DqError> {
        Ok(self.manager.stats())
    }

    fn worker_count(&self) -> usize {
        self.manager.worker_count()
    }

    fn shutdown(&self) {
        InProcCluster::shutdown(self)
    }

    fn describe(&self) -> String {
        format!("in-proc cluster ({} workers)", self.manager.worker_count())
    }
}

impl ClusterClient for RemoteClient {
    fn session(&self) -> Result<ClientSession, DqError> {
        RemoteClient::session(self)
    }

    fn register(
        &self,
        _profile: WorkerProfile,
        _channel: Arc<dyn WorkerChannel>,
    ) -> Result<WorkerId, DqError> {
        Err(DqError::Protocol(
            "remote workers register by dialing the manager themselves \
             (worker::WorkerHandle::start); a client connection cannot \
             inject a channel"
                .into(),
        ))
    }

    fn stats(&self) -> Result<ManagerStats, DqError> {
        RemoteClient::stats(self).map(|(s, _, _)| s)
    }

    fn worker_count(&self) -> usize {
        RemoteClient::stats(self).map(|(_, w, _)| w as usize).unwrap_or(0)
    }

    fn shutdown(&self) {
        // The server is owned by the remote process; dropping the
        // connection is the only local teardown.
    }

    fn describe(&self) -> String {
        format!(
            "remote client #{} ({} plane)",
            self.client_id(),
            if self.is_binary() { "binary" } else { "json" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::QuClassiConfig;
    use crate::coordinator::ManagerConfig;
    use crate::model::exec::CircuitPair;

    /// The same generic driver runs a bank against any backend — the
    /// portability claim the trait exists for.
    fn drive(cluster: &dyn ClusterClient) {
        let session = cluster.session().unwrap();
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs: Vec<CircuitPair> = vec![(vec![0.2; 4], vec![0.7; 4]); 4];
        let fids = session.execute(cfg, &pairs).unwrap();
        assert_eq!(fids.len(), 4);
        let stats = cluster.stats().unwrap();
        assert!(stats.completed >= 4);
        assert!(cluster.worker_count() >= 1);
    }

    #[test]
    fn trait_objects_cover_local_backends() {
        let inproc = InProcCluster::builder().workers(&[5]).build().unwrap();
        drive(&inproc);
        assert!(ClusterClient::describe(&inproc).contains("in-proc"));
        inproc.shutdown();

        let manager = Manager::new(ManagerConfig::default());
        // reuse the in-proc worker channel shape via a sharded pool too
        let sm = ShardManager::new(crate::coordinator::ShardConfig {
            shards: 2,
            manager: ManagerConfig::default(),
            ..Default::default()
        });
        for pool in [&manager as &dyn ClusterClient, &sm as &dyn ClusterClient] {
            let session = pool.session().unwrap();
            assert!(session.id() >= 1);
        }
        assert!(ClusterClient::describe(&sm).contains("2 shards"));
        manager.shutdown();
        sm.shutdown();
    }
}
