//! In-process cluster: the full co-Manager + worker stack on threads.
//!
//! Used by tests, the quickstart example, and calibration runs. Workers
//! execute through their configured backend (PJRT artifacts or qsim);
//! the manager code path is byte-for-byte the one used over TCP — only
//! the `WorkerChannel` is a direct call instead of an RPC. Each worker's
//! registration spawns its per-worker outbox dispatcher inside the
//! manager (DESIGN.md §13), so even in-proc execution is sharded: a slow
//! backend stalls only its own outbox, never dispatch to siblings.

use std::path::PathBuf;
use std::sync::Arc;

use crate::circuit::QuClassiConfig;
use crate::coordinator::{ClientSession, Manager, ManagerConfig, WorkerChannel, WorkerProfile};
use crate::error::DqError;
use crate::model::exec::{CircuitExecutor, CircuitPair};
use crate::qsim::NoiseModel;
use crate::worker::WorkerBackend;

/// Direct-call worker channel wrapping a backend.
struct InProcChannel {
    backend: WorkerBackend,
}

impl WorkerChannel for InProcChannel {
    fn execute(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        self.backend.execute(config, pairs)
    }
}

/// Builder for an in-process cluster.
pub struct InProcClusterBuilder {
    worker_qubits: Vec<usize>,
    /// Per-worker noise models (heterogeneous pools; extension §10).
    worker_noise: Vec<Option<NoiseModel>>,
    artifacts: Option<PathBuf>,
    manager_config: ManagerConfig,
    noise: Option<NoiseModel>,
    /// Simulator thread budget per worker (DESIGN.md §11): 1 = serial
    /// backend (default), 0 = detect from the host, N = fixed pool.
    threads: usize,
}

/// A running in-process cluster.
pub struct InProcCluster {
    pub manager: Manager,
    client: u64,
}

impl InProcCluster {
    pub fn builder() -> InProcClusterBuilder {
        InProcClusterBuilder {
            worker_qubits: vec![5],
            worker_noise: Vec::new(),
            artifacts: None,
            manager_config: ManagerConfig::default(),
            noise: None,
            threads: 1,
        }
    }
}

impl InProcClusterBuilder {
    /// One worker per entry, each with the given max qubits.
    pub fn workers(mut self, qubits: &[usize]) -> Self {
        self.worker_qubits = qubits.to_vec();
        self
    }

    /// Use PJRT backends loading artifacts from this directory.
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = Some(dir.into());
        self
    }

    pub fn manager_config(mut self, cfg: ManagerConfig) -> Self {
        self.manager_config = cfg;
        self
    }

    /// Give every worker a noisy simulator backend (extension).
    pub fn noise(mut self, noise: NoiseModel) -> Self {
        self.noise = Some(noise);
        self
    }

    /// Heterogeneous pool: per-worker (qubits, noise model) profiles.
    pub fn workers_with_noise(mut self, profiles: &[(usize, Option<NoiseModel>)]) -> Self {
        self.worker_qubits = profiles.iter().map(|(q, _)| *q).collect();
        self.worker_noise = profiles.iter().map(|(_, n)| *n).collect();
        self
    }

    /// Give every noiseless worker an internal simulator thread pool of
    /// `threads` (`0` = detect from the host). Results stay bitwise
    /// identical to the serial backend; only throughput changes
    /// (DESIGN.md §11). Workers with a noise model keep the serial
    /// trajectory backend — its single RNG stream is inherently
    /// order-dependent — and register a thread budget of 1.
    pub fn worker_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Assemble and start the cluster.
    pub fn build(self) -> Result<InProcCluster, DqError> {
        let manager = Manager::new(self.manager_config);
        let threads = if self.threads == 0 {
            crate::model::exec::detect_threads()
        } else {
            self.threads
        };
        for (i, &mq) in self.worker_qubits.iter().enumerate() {
            let per_worker = self.worker_noise.get(i).copied().flatten().or(self.noise);
            let backend = match (&per_worker, &self.artifacts) {
                (Some(nm), _) => WorkerBackend::NoisyQsim(*nm, 0x5EED + i as u64),
                (None, Some(dir)) => WorkerBackend::auto_with_threads(dir, threads),
                (None, None) if threads > 1 => {
                    WorkerBackend::ParallelQsim(crate::model::exec::ParallelQsimExecutor::new(
                        threads,
                    ))
                }
                (None, None) => WorkerBackend::Qsim,
            };
            // report gate-error magnitude as the noise estimate
            let noise_level = per_worker.map(|n| n.p2).unwrap_or(0.0);
            manager.register(
                WorkerProfile::new(mq).noise(noise_level).threads(backend.threads()),
                Arc::new(InProcChannel { backend }),
            );
        }
        let client = manager.new_client();
        Ok(InProcCluster { manager, client })
    }
}

impl InProcCluster {
    /// A typed [`ClientSession`] for a fresh tenant (the preferred entry
    /// point: submit returns a pollable/cancellable `BankHandle`).
    pub fn session(&self) -> ClientSession {
        self.manager.session()
    }

    /// A raw client id.
    #[deprecated(
        since = "0.1.0",
        note = "use InProcCluster::session (typed, portable across every ClusterClient backend)"
    )]
    pub fn new_client(&self) -> u64 {
        self.manager.new_client()
    }

    pub fn shutdown(&self) {
        self.manager.shutdown();
    }
}

/// The cluster is itself a [`CircuitExecutor`]: the Trainer runs
/// distributed without code changes.
impl CircuitExecutor for InProcCluster {
    fn execute_bank(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        self.manager.execute_bank(self.client, *config, pairs)
    }

    fn describe(&self) -> String {
        format!("in-proc cluster ({} workers)", self.manager.worker_count())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::model::exec::QsimExecutor;
    use crate::model::optimizer::Optimizer;
    use crate::model::quclassi::LossKind;
    use crate::model::{QuClassiModel, TrainConfig, Trainer};
    use crate::util::Rng;

    #[test]
    fn parallel_workers_match_serial_cluster_bitwise() {
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let mut rng = Rng::new(77);
        let pairs: Vec<CircuitPair> = (0..40)
            .map(|_| {
                (
                    (0..cfg.n_params()).map(|_| rng.f32()).collect(),
                    (0..cfg.n_features()).map(|_| rng.f32()).collect(),
                )
            })
            .collect();
        let serial = InProcCluster::builder().workers(&[5, 5]).build().unwrap();
        let parallel =
            InProcCluster::builder().workers(&[5, 5]).worker_threads(4).build().unwrap();
        let a = serial.execute_bank(&cfg, &pairs).unwrap();
        let b = parallel.execute_bank(&cfg, &pairs).unwrap();
        assert_eq!(a, b);
        assert_eq!(a, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        serial.shutdown();
        parallel.shutdown();
    }

    #[test]
    fn cluster_matches_local_execution() {
        let cluster = InProcCluster::builder().workers(&[5, 5]).build().unwrap();
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let mut rng = Rng::new(11);
        let pairs: Vec<CircuitPair> = (0..25)
            .map(|_| {
                (
                    (0..cfg.n_params()).map(|_| rng.f32()).collect(),
                    (0..cfg.n_features()).map(|_| rng.f32()).collect(),
                )
            })
            .collect();
        let dist = cluster.execute_bank(&cfg, &pairs).unwrap();
        let local = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
        assert_eq!(dist, local);
        cluster.shutdown();
    }

    /// The paper's central accuracy claim: distributed training reaches
    /// (almost) the same accuracy as the non-distributed baseline — here
    /// they are bitwise-identical computations, so accuracies match when
    /// seeds match.
    #[test]
    fn distributed_training_equals_baseline() {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let ds = Dataset::binary_pair(None, 3, 9, 10, 5);
        let tc = TrainConfig {
            epochs: 3,
            optimizer: Optimizer::adam(0.1),
            train_classical: false,
            classical_lr_scale: 0.1,
            seed: 3,
            early_stop_acc: None,
            loss: LossKind::Discriminative,
        };

        let mut m1 = QuClassiModel::new(cfg, &mut Rng::new(9));
        let baseline = Trainer::new(tc.clone()).train(&mut m1, &ds, &QsimExecutor).unwrap();

        let cluster = InProcCluster::builder().workers(&[5, 5]).build().unwrap();
        let mut m2 = QuClassiModel::new(cfg, &mut Rng::new(9));
        let distributed = Trainer::new(tc).train(&mut m2, &ds, &cluster).unwrap();

        assert_eq!(m1.theta[0], m2.theta[0], "theta_A diverged");
        assert!(
            (baseline.final_train_accuracy() - distributed.final_train_accuracy()).abs() < 1e-9
        );
        cluster.shutdown();
    }

    #[test]
    fn heterogeneous_workers_multi_tenant() {
        // workers 5/10/15/20 qubits — the paper's multi-tenant pool
        let cluster = InProcCluster::builder().workers(&[5, 10, 15, 20]).build().unwrap();
        let cfg5 = QuClassiConfig::new(5, 1).unwrap();
        let cfg7 = QuClassiConfig::new(7, 2).unwrap();
        let mut rng = Rng::new(3);
        let mk = |cfg: &QuClassiConfig, rng: &mut Rng, n: usize| -> Vec<CircuitPair> {
            (0..n)
                .map(|_| {
                    (
                        (0..cfg.n_params()).map(|_| rng.f32()).collect(),
                        (0..cfg.n_features()).map(|_| rng.f32()).collect(),
                    )
                })
                .collect()
        };
        let p5 = mk(&cfg5, &mut rng, 16);
        let p7 = mk(&cfg7, &mut rng, 16);
        let c5 = cluster.manager.clone();
        let c7 = cluster.manager.clone();
        let p5c = p5.clone();
        let p7c = p7.clone();
        let t5 = std::thread::spawn(move || c5.execute_bank(c5.new_client(), cfg5, &p5c).unwrap());
        let t7 = std::thread::spawn(move || c7.execute_bank(c7.new_client(), cfg7, &p7c).unwrap());
        let got5 = t5.join().unwrap();
        let got7 = t7.join().unwrap();
        assert_eq!(got5, QsimExecutor.execute_bank(&cfg5, &p5).unwrap());
        assert_eq!(got7, QsimExecutor.execute_bank(&cfg7, &p7).unwrap());
        cluster.shutdown();
    }

    #[test]
    fn noisy_cluster_produces_different_fidelities() {
        let clean = InProcCluster::builder().workers(&[5]).build().unwrap();
        let noisy = InProcCluster::builder()
            .workers(&[5])
            .noise(NoiseModel { p1: 0.2, p2: 0.3, readout: 0.1 })
            .build()
            .unwrap();
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let pairs: Vec<CircuitPair> = vec![(vec![0.4; 6], vec![0.9; 4]); 6];
        let a = clean.execute_bank(&cfg, &pairs).unwrap();
        let b = noisy.execute_bank(&cfg, &pairs).unwrap();
        assert_ne!(a, b);
        clean.shutdown();
        noisy.shutdown();
    }
}
