//! Typed client↔manager wire messages.
//!
//! `cluster::tcp` historically assembled submit payloads out of ad-hoc
//! [`Value`] objects on both ends; these structs are the single source of
//! truth for the field layout now, with symmetric `to_wire`/`from_wire`
//! codecs (and round-trip tests). The manager→worker `execute` payload
//! is already typed by [`crate::coordinator::CircuitJob`].
//!
//! Protocol ops (framed JSON, `net::rpc` envelope; each has a binary
//! twin in `wire::bin` served from the same port's mux plane):
//!
//! ```text
//! client -> manager : new_client {}                      -> {client}
//! client -> manager : submit_bank <SubmitRequest>        -> <SubmitResponse>
//! client -> manager : wait_bank   {bank, timeout_ms?}    -> {fids}
//! client -> manager : bank_status {bank}                 -> <BankStatus wire>
//! client -> manager : cancel_bank {bank}                 -> {drained}
//! client -> manager : stats {}                           -> <ManagerStats wire>
//! ```
//!
//! Binary-only ops (no JSON twin — they need the mux plane's push
//! frames and reconnect machinery, DESIGN.md §19):
//!
//! ```text
//! client -> manager : subscribe_bank {bank}    -> stream of <BankEvent>
//!                     (unsolicited server-push frames on the request's
//!                      correlation id; terminal event closes the stream)
//! client -> manager : attach {token}           -> {token, resumed, last_req_corr}
//!                     (re-binds a torn-down connection to its server
//!                      session; the watermark drives exactly-once replay)
//! ```
//!
//! JSON peers fall back to polling `bank_status`; `BankHandle::try_poll`
//! on a push-negotiated connection answers from the streamed events
//! without touching the wire.
//!
//! The `stats` payload carries the full [`ManagerStats`] — aggregate
//! counters (incl. `steals` and retention fields) plus one entry per
//! retained tenant with its 8-bucket queue-wait histogram — so remote
//! operators read manager-computed p50/p90 waits instead of recomputing
//! percentiles client-side.

use std::collections::BTreeMap;

use crate::circuit::QuClassiConfig;
use crate::coordinator::{BankStatus, ManagerStats, TenantStats};
use crate::error::DqError;
use crate::model::exec::CircuitPair;
use crate::util::stats::{WaitHistogram, WAIT_HIST_BUCKETS};
use crate::wire::Value;

/// A client's `submit_bank` request: one config, many circuits.
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitRequest {
    pub client: u64,
    pub config: QuClassiConfig,
    pub pairs: Vec<CircuitPair>,
}

impl SubmitRequest {
    pub fn to_wire(&self) -> Value {
        let circuits: Vec<Value> = self
            .pairs
            .iter()
            .map(|(t, d)| Value::obj().with("thetas", t.as_slice()).with("data", d.as_slice()))
            .collect();
        Value::obj()
            .with("client", self.client)
            .with("qubits", self.config.qubits)
            .with("layers", self.config.layers)
            .with("circuits", circuits)
    }

    pub fn from_wire(v: &Value) -> Result<SubmitRequest, DqError> {
        let config = QuClassiConfig::new(v.req_usize("qubits")?, v.req_usize("layers")?)?;
        let circuits = v.req_arr("circuits")?;
        let mut pairs = Vec::with_capacity(circuits.len());
        for c in circuits {
            pairs.push((c.req_f32_vec("thetas")?, c.req_f32_vec("data")?));
        }
        Ok(SubmitRequest { client: v.req_u64("client")?, config, pairs })
    }
}

/// The manager's answer to `submit_bank`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubmitResponse {
    /// The opened bank's id (the handle key for wait/status/cancel).
    pub bank: u64,
    /// Circuits accepted into the bank.
    pub total: usize,
}

impl SubmitResponse {
    pub fn to_wire(&self) -> Value {
        Value::obj().with("bank", self.bank).with("total", self.total)
    }

    pub fn from_wire(v: &Value) -> Result<SubmitResponse, DqError> {
        Ok(SubmitResponse { bank: v.req_u64("bank")?, total: v.req_usize("total")? })
    }
}

/// Wire form of [`BankStatus`]: per-circuit fidelities as an array of
/// numbers and nulls.
pub fn bank_status_to_wire(s: &BankStatus) -> Value {
    let fids: Vec<Value> = s
        .partial_fids
        .iter()
        .map(|f| f.map(|x| Value::Num(x as f64)).unwrap_or(Value::Null))
        .collect();
    Value::obj()
        .with("pending", s.pending)
        .with("completed", s.completed)
        .with("total", s.total)
        .with("partial_fids", fids)
        .with("recovered", s.recovered)
}

/// Decode the wire form of [`BankStatus`].
pub fn bank_status_from_wire(v: &Value) -> Result<BankStatus, DqError> {
    let arr = v.req_arr("partial_fids")?;
    let partial_fids: Vec<Option<f32>> = arr.iter().map(|x| x.as_f64().map(|f| f as f32)).collect();
    Ok(BankStatus {
        pending: v
            .get("pending")
            .and_then(Value::as_bool)
            .ok_or_else(|| DqError::Protocol("missing/invalid bool field 'pending'".into()))?,
        completed: v.req_usize("completed")?,
        total: v.req_usize("total")?,
        partial_fids,
        // Absent on pre-journal peers: a bank not marked recovered was
        // submitted to the live manager incarnation (back-compat).
        recovered: v.get("recovered").and_then(Value::as_bool).unwrap_or(false),
    })
}

/// Wire form of one tenant's counters (an element of the `stats` op's
/// `tenants` array; also the `retired` aggregate with client 0).
pub fn tenant_stats_to_wire(client: u64, t: &TenantStats) -> Value {
    Value::obj()
        .with("client", client)
        .with("submitted", t.submitted)
        .with("dispatched", t.dispatched)
        .with("completed", t.completed)
        .with("lost", t.lost)
        .with("stolen", t.stolen)
        .with("wait_total_s", t.wait_total_s)
        .with("wait_max_s", t.wait_max_s)
        .with("wait_hist", t.wait_hist.counts().to_vec())
}

/// Decode one tenant's counters; the histogram must carry exactly
/// [`WAIT_HIST_BUCKETS`] integer buckets.
pub fn tenant_stats_from_wire(v: &Value) -> Result<(u64, TenantStats), DqError> {
    let counts: Vec<u64> = v
        .req_arr("wait_hist")?
        .iter()
        .map(|x| {
            x.as_u64()
                .ok_or_else(|| DqError::Protocol("non-integer wait_hist bucket".to_string()))
        })
        .collect::<Result<_, _>>()?;
    let wait_hist = WaitHistogram::from_counts(&counts).ok_or_else(|| {
        DqError::Protocol(format!(
            "wait_hist needs {WAIT_HIST_BUCKETS} buckets, got {}",
            counts.len()
        ))
    })?;
    Ok((
        v.req_u64("client")?,
        TenantStats {
            submitted: v.req_u64("submitted")?,
            dispatched: v.req_u64("dispatched")?,
            completed: v.req_u64("completed")?,
            lost: v.req_u64("lost")?,
            stolen: v.req_u64("stolen")?,
            wait_total_s: v.req_f64("wait_total_s")?,
            wait_max_s: v.req_f64("wait_max_s")?,
            wait_hist,
        },
    ))
}

/// Wire form of the manager's aggregate + per-tenant counters (the
/// `stats` op payload; `cluster::tcp` adds live `workers`/`queue`
/// gauges on top).
pub fn manager_stats_to_wire(s: &ManagerStats) -> Value {
    let tenants: Vec<Value> =
        s.per_tenant.iter().map(|(client, t)| tenant_stats_to_wire(*client, t)).collect();
    Value::obj()
        .with("submitted", s.submitted)
        .with("completed", s.completed)
        .with("dispatches", s.dispatches)
        .with("requeues", s.requeues)
        .with("evictions", s.evictions)
        .with("cancelled", s.cancelled)
        .with("steals", s.steals)
        .with("pruned_tenants", s.pruned_tenants)
        .with("retired", tenant_stats_to_wire(0, &s.retired))
        .with("tenants", tenants)
}

/// Decode the `stats` payload back into a [`ManagerStats`].
pub fn manager_stats_from_wire(v: &Value) -> Result<ManagerStats, DqError> {
    let mut per_tenant = BTreeMap::new();
    for t in v.req_arr("tenants")? {
        let (client, stats) = tenant_stats_from_wire(t)?;
        per_tenant.insert(client, stats);
    }
    let retired = tenant_stats_from_wire(
        v.get("retired")
            .ok_or_else(|| DqError::Protocol("missing 'retired' aggregate".to_string()))?,
    )?
    .1;
    Ok(ManagerStats {
        submitted: v.req_u64("submitted")?,
        completed: v.req_u64("completed")?,
        dispatches: v.req_u64("dispatches")?,
        requeues: v.req_u64("requeues")?,
        evictions: v.req_u64("evictions")?,
        cancelled: v.req_u64("cancelled")?,
        steals: v.req_u64("steals")?,
        pruned_tenants: v.req_u64("pruned_tenants")?,
        retired,
        per_tenant,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_request_round_trips() {
        let req = SubmitRequest {
            client: 3,
            config: QuClassiConfig::new(5, 2).unwrap(),
            pairs: vec![
                (vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6], vec![0.9; 4]),
                (vec![0.0; 6], vec![-1.5, 0.25, 0.0, 2.0]),
            ],
        };
        let back = SubmitRequest::from_wire(&req.to_wire()).unwrap();
        assert_eq!(req, back);
        // and through the actual JSON serializer
        let text = crate::wire::json::to_string(&req.to_wire());
        let parsed = crate::wire::json::parse(&text).unwrap();
        assert_eq!(SubmitRequest::from_wire(&parsed).unwrap(), req);
    }

    #[test]
    fn submit_request_rejects_bad_config() {
        let mut w = SubmitRequest {
            client: 1,
            config: QuClassiConfig::new(5, 1).unwrap(),
            pairs: vec![(vec![0.0; 4], vec![0.0; 4])],
        }
        .to_wire();
        w.set("qubits", 4usize); // even widths are invalid
        assert!(matches!(SubmitRequest::from_wire(&w), Err(DqError::Protocol(_))));
    }

    #[test]
    fn submit_response_round_trips() {
        let resp = SubmitResponse { bank: 42, total: 128 };
        assert_eq!(SubmitResponse::from_wire(&resp.to_wire()).unwrap(), resp);
    }

    #[test]
    fn bank_status_round_trips_with_nulls() {
        let status = BankStatus {
            pending: true,
            completed: 2,
            total: 4,
            partial_fids: vec![Some(0.5), None, Some(0.25), None],
            recovered: true,
        };
        let text = crate::wire::json::to_string(&bank_status_to_wire(&status));
        let parsed = crate::wire::json::parse(&text).unwrap();
        assert_eq!(bank_status_from_wire(&parsed).unwrap(), status);
    }

    #[test]
    fn bank_status_recovered_defaults_false() {
        // A pre-journal peer omits the field: decode must not fail and
        // must report a non-recovered bank.
        let v = Value::obj()
            .with("pending", false)
            .with("completed", 1u64)
            .with("total", 1u64)
            .with("partial_fids", vec![Value::Num(0.5)]);
        let status = bank_status_from_wire(&v).unwrap();
        assert!(!status.recovered);
    }

    #[test]
    fn bank_status_missing_fields_is_protocol() {
        let v = Value::obj().with("completed", 1u64);
        assert!(matches!(bank_status_from_wire(&v), Err(DqError::Protocol(_))));
    }

    fn sample_tenant() -> TenantStats {
        let mut wait_hist = WaitHistogram::new();
        wait_hist.record(0.0004);
        wait_hist.record(0.02);
        wait_hist.record(2.5);
        TenantStats {
            submitted: 100,
            dispatched: 98,
            completed: 95,
            lost: 5,
            stolen: 7,
            wait_total_s: 1.25,
            wait_max_s: 0.5,
            wait_hist,
        }
    }

    #[test]
    fn tenant_stats_round_trips_through_json() {
        let t = sample_tenant();
        let text = crate::wire::json::to_string(&tenant_stats_to_wire(42, &t));
        let parsed = crate::wire::json::parse(&text).unwrap();
        let (client, back) = tenant_stats_from_wire(&parsed).unwrap();
        assert_eq!(client, 42);
        assert_eq!(back.submitted, t.submitted);
        assert_eq!(back.lost, t.lost);
        assert_eq!(back.stolen, t.stolen);
        assert_eq!(back.wait_hist, t.wait_hist);
        assert_eq!(back.wait_hist.total(), 3);
    }

    #[test]
    fn tenant_stats_rejects_malformed_histogram() {
        let mut w = tenant_stats_to_wire(1, &sample_tenant());
        w.set("wait_hist", vec![1u64, 2, 3]); // wrong bucket count
        assert!(matches!(tenant_stats_from_wire(&w), Err(DqError::Protocol(_))));
        let mut w = tenant_stats_to_wire(1, &sample_tenant());
        w.set("wait_hist", vec![0.5f64; WAIT_HIST_BUCKETS]); // non-integer
        assert!(matches!(tenant_stats_from_wire(&w), Err(DqError::Protocol(_))));
    }

    #[test]
    fn manager_stats_round_trips_through_json() {
        let mut stats = ManagerStats {
            submitted: 1000,
            completed: 990,
            dispatches: 130,
            requeues: 4,
            evictions: 1,
            cancelled: 2,
            steals: 11,
            pruned_tenants: 3,
            retired: sample_tenant(),
            per_tenant: BTreeMap::new(),
        };
        stats.per_tenant.insert(7, sample_tenant());
        stats.per_tenant.insert(9, TenantStats::default());
        let text = crate::wire::json::to_string(&manager_stats_to_wire(&stats));
        let parsed = crate::wire::json::parse(&text).unwrap();
        let back = manager_stats_from_wire(&parsed).unwrap();
        assert_eq!(back.steals, 11);
        assert_eq!(back.pruned_tenants, 3);
        assert_eq!(back.retired.stolen, 7);
        assert_eq!(back.per_tenant.len(), 2);
        assert_eq!(back.per_tenant[&7].wait_hist, stats.per_tenant[&7].wait_hist);
        // manager-reported quantiles survive the wire: p90 is answerable
        // remotely without raw samples
        assert!(back.per_tenant[&7].wait_hist.p50() > 0.0);
    }
}
