//! Typed client↔manager wire messages.
//!
//! `cluster::tcp` historically assembled submit payloads out of ad-hoc
//! [`Value`] objects on both ends; these structs are the single source of
//! truth for the field layout now, with symmetric `to_wire`/`from_wire`
//! codecs (and round-trip tests). The manager→worker `execute` payload
//! is already typed by [`crate::coordinator::CircuitJob`].
//!
//! Protocol ops (all framed JSON, `net::rpc` envelope):
//!
//! ```text
//! client -> manager : new_client {}                      -> {client}
//! client -> manager : submit_bank <SubmitRequest>        -> <SubmitResponse>
//! client -> manager : wait_bank   {bank, timeout_ms?}    -> {fids}
//! client -> manager : bank_status {bank}                 -> <BankStatus wire>
//! client -> manager : cancel_bank {bank}                 -> {drained}
//! ```

use crate::circuit::QuClassiConfig;
use crate::coordinator::BankStatus;
use crate::error::DqError;
use crate::model::exec::CircuitPair;
use crate::wire::Value;

/// A client's `submit_bank` request: one config, many circuits.
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitRequest {
    pub client: u64,
    pub config: QuClassiConfig,
    pub pairs: Vec<CircuitPair>,
}

impl SubmitRequest {
    pub fn to_wire(&self) -> Value {
        let circuits: Vec<Value> = self
            .pairs
            .iter()
            .map(|(t, d)| Value::obj().with("thetas", t.as_slice()).with("data", d.as_slice()))
            .collect();
        Value::obj()
            .with("client", self.client)
            .with("qubits", self.config.qubits)
            .with("layers", self.config.layers)
            .with("circuits", circuits)
    }

    pub fn from_wire(v: &Value) -> Result<SubmitRequest, DqError> {
        let config = QuClassiConfig::new(v.req_usize("qubits")?, v.req_usize("layers")?)?;
        let circuits = v.req_arr("circuits")?;
        let mut pairs = Vec::with_capacity(circuits.len());
        for c in circuits {
            pairs.push((c.req_f32_vec("thetas")?, c.req_f32_vec("data")?));
        }
        Ok(SubmitRequest { client: v.req_u64("client")?, config, pairs })
    }
}

/// The manager's answer to `submit_bank`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubmitResponse {
    /// The opened bank's id (the handle key for wait/status/cancel).
    pub bank: u64,
    /// Circuits accepted into the bank.
    pub total: usize,
}

impl SubmitResponse {
    pub fn to_wire(&self) -> Value {
        Value::obj().with("bank", self.bank).with("total", self.total)
    }

    pub fn from_wire(v: &Value) -> Result<SubmitResponse, DqError> {
        Ok(SubmitResponse { bank: v.req_u64("bank")?, total: v.req_usize("total")? })
    }
}

/// Wire form of [`BankStatus`]: per-circuit fidelities as an array of
/// numbers and nulls.
pub fn bank_status_to_wire(s: &BankStatus) -> Value {
    let fids: Vec<Value> = s
        .partial_fids
        .iter()
        .map(|f| f.map(|x| Value::Num(x as f64)).unwrap_or(Value::Null))
        .collect();
    Value::obj()
        .with("pending", s.pending)
        .with("completed", s.completed)
        .with("total", s.total)
        .with("partial_fids", fids)
}

/// Decode the wire form of [`BankStatus`].
pub fn bank_status_from_wire(v: &Value) -> Result<BankStatus, DqError> {
    let arr = v.req_arr("partial_fids")?;
    let partial_fids: Vec<Option<f32>> = arr.iter().map(|x| x.as_f64().map(|f| f as f32)).collect();
    Ok(BankStatus {
        pending: v
            .get("pending")
            .and_then(Value::as_bool)
            .ok_or_else(|| DqError::Protocol("missing/invalid bool field 'pending'".into()))?,
        completed: v.req_usize("completed")?,
        total: v.req_usize("total")?,
        partial_fids,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_request_round_trips() {
        let req = SubmitRequest {
            client: 3,
            config: QuClassiConfig::new(5, 2).unwrap(),
            pairs: vec![
                (vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6], vec![0.9; 4]),
                (vec![0.0; 6], vec![-1.5, 0.25, 0.0, 2.0]),
            ],
        };
        let back = SubmitRequest::from_wire(&req.to_wire()).unwrap();
        assert_eq!(req, back);
        // and through the actual JSON serializer
        let text = crate::wire::json::to_string(&req.to_wire());
        let parsed = crate::wire::json::parse(&text).unwrap();
        assert_eq!(SubmitRequest::from_wire(&parsed).unwrap(), req);
    }

    #[test]
    fn submit_request_rejects_bad_config() {
        let mut w = SubmitRequest {
            client: 1,
            config: QuClassiConfig::new(5, 1).unwrap(),
            pairs: vec![(vec![0.0; 4], vec![0.0; 4])],
        }
        .to_wire();
        w.set("qubits", 4usize); // even widths are invalid
        assert!(matches!(SubmitRequest::from_wire(&w), Err(DqError::Protocol(_))));
    }

    #[test]
    fn submit_response_round_trips() {
        let resp = SubmitResponse { bank: 42, total: 128 };
        assert_eq!(SubmitResponse::from_wire(&resp.to_wire()).unwrap(), resp);
    }

    #[test]
    fn bank_status_round_trips_with_nulls() {
        let status = BankStatus {
            pending: true,
            completed: 2,
            total: 4,
            partial_fids: vec![Some(0.5), None, Some(0.25), None],
        };
        let text = crate::wire::json::to_string(&bank_status_to_wire(&status));
        let parsed = crate::wire::json::parse(&text).unwrap();
        assert_eq!(bank_status_from_wire(&parsed).unwrap(), status);
    }

    #[test]
    fn bank_status_missing_fields_is_protocol() {
        let v = Value::obj().with("completed", 1u64);
        assert!(matches!(bank_status_from_wire(&v), Err(DqError::Protocol(_))));
    }
}
