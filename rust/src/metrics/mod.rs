//! Runtime metrics: epoch timers, throughput meters, latency recorders.
//!
//! The paper's evaluation reports two quantities per experiment —
//! runtime per epoch and circuits processed per second — plus accuracy.
//! This module provides the accounting used by the live system (the DES
//! computes its own inside `env::sim`).

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Summary;

/// Wall-clock epoch timer (Algorithm 1 lines 5/24-25).
#[derive(Debug)]
pub struct EpochTimer {
    start: Instant,
    laps: Vec<f64>,
}

impl EpochTimer {
    pub fn start() -> EpochTimer {
        EpochTimer { start: Instant::now(), laps: Vec::new() }
    }

    /// Record the end of an epoch and restart the timer.
    pub fn lap(&mut self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        self.laps.push(secs);
        self.start = Instant::now();
        secs
    }

    pub fn laps(&self) -> &[f64] {
        &self.laps
    }

    pub fn total(&self) -> f64 {
        self.laps.iter().sum()
    }
}

impl Default for EpochTimer {
    fn default() -> Self {
        Self::start()
    }
}

/// Thread-safe circuits-per-second meter.
#[derive(Debug)]
pub struct ThroughputMeter {
    inner: Mutex<ThroughputInner>,
}

#[derive(Debug)]
struct ThroughputInner {
    start: Instant,
    circuits: u64,
}

impl ThroughputMeter {
    pub fn start() -> ThroughputMeter {
        ThroughputMeter {
            inner: Mutex::new(ThroughputInner { start: Instant::now(), circuits: 0 }),
        }
    }

    pub fn add(&self, circuits: u64) {
        self.inner.lock().expect("meter poisoned").circuits += circuits;
    }

    pub fn circuits(&self) -> u64 {
        self.inner.lock().expect("meter poisoned").circuits
    }

    /// Circuits per second since start.
    pub fn cps(&self) -> f64 {
        let g = self.inner.lock().expect("meter poisoned");
        g.circuits as f64 / g.start.elapsed().as_secs_f64().max(1e-9)
    }
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::start()
    }
}

/// Latency recorder with summary statistics (per-bank round trips).
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    samples: Mutex<Vec<f64>>,
}

impl LatencyRecorder {
    pub fn new() -> LatencyRecorder {
        LatencyRecorder::default()
    }

    pub fn record(&self, secs: f64) {
        self.samples.lock().expect("recorder poisoned").push(secs);
    }

    /// Time a closure and record its latency.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.record(t.elapsed().as_secs_f64());
        out
    }

    pub fn count(&self) -> usize {
        self.samples.lock().expect("recorder poisoned").len()
    }

    pub fn summary(&self) -> Option<Summary> {
        let g = self.samples.lock().expect("recorder poisoned");
        if g.is_empty() {
            None
        } else {
            Some(Summary::of(&g))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_timer_accumulates_laps() {
        let mut t = EpochTimer::start();
        std::thread::sleep(std::time::Duration::from_millis(10));
        let lap1 = t.lap();
        assert!(lap1 >= 0.009);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let lap2 = t.lap();
        assert_eq!(t.laps().len(), 2);
        assert!((t.total() - (lap1 + lap2)).abs() < 1e-9);
    }

    #[test]
    fn throughput_meter_counts() {
        let m = ThroughputMeter::start();
        m.add(100);
        m.add(50);
        assert_eq!(m.circuits(), 150);
        assert!(m.cps() > 0.0);
    }

    #[test]
    fn latency_recorder_summarizes() {
        let r = LatencyRecorder::new();
        assert!(r.summary().is_none());
        for i in 1..=10 {
            r.record(i as f64 / 1000.0);
        }
        let s = r.summary().unwrap();
        assert_eq!(s.count, 10);
        assert!((s.mean - 0.0055).abs() < 1e-9);
        let out = r.time(|| 42);
        assert_eq!(out, 42);
        assert_eq!(r.count(), 11);
    }
}
