//! From-scratch command-line parsing (std-only substrate for `clap`).
//!
//! Declarative subcommand + flag/option specs with generated `--help`,
//! type-checked value access, and unknown-argument errors.

use std::collections::BTreeMap;

/// An option/flag specification.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// A subcommand specification.
#[derive(Debug, Clone)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl CommandSpec {
    pub fn new(name: &'static str, about: &'static str) -> CommandSpec {
        CommandSpec { name, about, opts: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> CommandSpec {
        self.opts.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> CommandSpec {
        self.opts.push(OptSpec { name, help, takes_value: true, default: None });
        self
    }

    pub fn opt_default(
        mut self,
        name: &'static str,
        help: &'static str,
        default: &'static str,
    ) -> CommandSpec {
        self.opts.push(OptSpec { name, help, takes_value: true, default: Some(default) });
        self
    }
}

/// A parsed command line.
#[derive(Debug, Clone)]
pub struct Parsed {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<usize>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: expected an integer, got '{s}'"))),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<f64>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: expected a number, got '{s}'"))),
        }
    }

    /// Parse a comma-separated list of integers ("5,10,15,20").
    pub fn get_usize_list(&self, name: &str) -> Result<Option<Vec<usize>>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<usize>()
                        .map_err(|_| CliError(format!("--{name}: bad integer '{p}'")))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }
}

/// Parse failure with a user-facing message.
#[derive(Debug, Clone, PartialEq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// The application spec: name, version, subcommands.
pub struct App {
    pub name: &'static str,
    pub version: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

impl App {
    pub fn parse(&self, args: &[String]) -> Result<Parsed, CliError> {
        if args.is_empty() {
            return Err(CliError(self.usage()));
        }
        let cmd_name = &args[0];
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(CliError(self.usage()));
        }
        if cmd_name == "--version" {
            return Err(CliError(format!("{} {}", self.name, self.version)));
        }
        let spec = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| CliError(format!("unknown command '{cmd_name}'\n\n{}", self.usage())))?;

        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        for opt in &spec.opts {
            if let Some(d) = opt.default {
                values.insert(opt.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < args.len() {
            let arg = &args[i];
            if arg == "--help" || arg == "-h" {
                return Err(CliError(self.command_usage(spec)));
            }
            if let Some(name) = arg.strip_prefix("--") {
                // --name=value form
                let (name, inline_val) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let opt = spec
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| {
                        CliError(format!("unknown option '--{name}'\n\n{}", self.command_usage(spec)))
                    })?;
                if opt.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} requires a value")))?
                        }
                    };
                    values.insert(name.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Err(CliError(format!("flag --{name} takes no value")));
                    }
                    flags.push(name.to_string());
                }
            } else {
                positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(Parsed { command: spec.name.to_string(), values, flags, positional })
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} {} — {}\n\nUSAGE:\n  {} <COMMAND> [OPTIONS]\n\nCOMMANDS:\n",
            self.name, self.version, self.about, self.name);
        for c in &self.commands {
            out.push_str(&format!("  {:<18} {}\n", c.name, c.about));
        }
        out.push_str("\nRun '<COMMAND> --help' for command options.");
        out
    }

    pub fn command_usage(&self, spec: &CommandSpec) -> String {
        let mut out = format!("{} {} — {}\n\nOPTIONS:\n", self.name, spec.name, spec.about);
        for o in &spec.opts {
            let val = if o.takes_value { " <VALUE>" } else { "" };
            let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            out.push_str(&format!("  --{:<22} {}{}\n", format!("{}{val}", o.name), o.help, def));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App {
            name: "dqulearn",
            version: "0.1.0",
            about: "test",
            commands: vec![
                CommandSpec::new("train", "train a model")
                    .opt_default("qubits", "qubit count", "5")
                    .opt("pair", "digit pair")
                    .flag("verbose", "chatty"),
                CommandSpec::new("worker", "run worker").opt("manager", "manager addr"),
            ],
        }
    }

    fn parse(args: &[&str]) -> Result<Parsed, CliError> {
        app().parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn defaults_apply() {
        let p = parse(&["train"]).unwrap();
        assert_eq!(p.get("qubits"), Some("5"));
        assert_eq!(p.get("pair"), None);
        assert!(!p.has_flag("verbose"));
    }

    #[test]
    fn values_and_flags() {
        let p = parse(&["train", "--qubits", "7", "--pair=3,9", "--verbose"]).unwrap();
        assert_eq!(p.get_usize("qubits").unwrap(), Some(7));
        assert_eq!(p.get("pair"), Some("3,9"));
        assert!(p.has_flag("verbose"));
    }

    #[test]
    fn usize_list() {
        let p = parse(&["train", "--pair", "5, 10,15"]).unwrap();
        assert_eq!(p.get_usize_list("pair").unwrap(), Some(vec![5, 10, 15]));
    }

    #[test]
    fn unknown_command_and_option() {
        assert!(parse(&["nope"]).is_err());
        assert!(parse(&["train", "--bogus"]).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&["train", "--qubits"]).is_err());
    }

    #[test]
    fn flag_with_value_is_error() {
        assert!(parse(&["train", "--verbose=yes"]).is_err());
    }

    #[test]
    fn bad_int_reports_option() {
        let p = parse(&["train", "--qubits", "five"]).unwrap();
        let err = p.get_usize("qubits").unwrap_err();
        assert!(err.0.contains("qubits"));
    }

    #[test]
    fn help_lists_commands() {
        let err = parse(&["--help"]).unwrap_err();
        assert!(err.0.contains("train"));
        assert!(err.0.contains("worker"));
    }
}
