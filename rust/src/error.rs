//! `DqError` — the crate-wide error taxonomy.
//!
//! Every public fallible API in `coordinator/`, `cluster/`, `net/`, and
//! `worker/` (and the [`crate::model::CircuitExecutor`] boundary they all
//! implement) returns `Result<_, DqError>` instead of the historical
//! `Result<_, String>`. The taxonomy is deliberately small — seven
//! variants cover every failure the distributed system can produce — and
//! each variant round-trips through the framed-JSON RPC envelope
//! ([`DqError::to_wire`] / [`DqError::from_wire`]), so a remote client
//! observes the *same* typed error the manager raised, not a flattened
//! string.
//!
//! | variant          | raised when                                            |
//! |------------------|--------------------------------------------------------|
//! | `Unschedulable`  | no worker in the pool can ever fit a circuit           |
//! | `WorkerLost`     | a worker evicted / unknown at heartbeat or dispatch    |
//! | `Timeout`        | a bank wait exceeded its deadline                      |
//! | `Cancelled`      | a bank was cancelled (or the manager shut down)        |
//! | `Protocol`       | malformed frames, payload arity/shape violations       |
//! | `Arity`          | client-side input validation (theta/data lengths)      |
//! | `Io`             | socket / filesystem failures                           |

use crate::wire::Value;

/// The crate-wide error taxonomy (see module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DqError {
    /// The circuit can never be placed on the current worker pool.
    Unschedulable(String),
    /// The addressed worker is not (or no longer) registered.
    WorkerLost(String),
    /// A wait exceeded its deadline.
    Timeout(String),
    /// The operation's bank was cancelled, or the manager stopped.
    Cancelled(String),
    /// Wire-level violation: malformed frame, bad field, short payload.
    Protocol(String),
    /// Input validation: theta/data vector lengths do not match a config.
    Arity(String),
    /// Underlying transport or filesystem failure.
    Io(String),
}

impl DqError {
    /// Stable kind tag used on the wire and in logs.
    pub fn kind(&self) -> &'static str {
        match self {
            DqError::Unschedulable(_) => "unschedulable",
            DqError::WorkerLost(_) => "worker_lost",
            DqError::Timeout(_) => "timeout",
            DqError::Cancelled(_) => "cancelled",
            DqError::Protocol(_) => "protocol",
            DqError::Arity(_) => "arity",
            DqError::Io(_) => "io",
        }
    }

    /// The human-readable detail message.
    pub fn message(&self) -> &str {
        match self {
            DqError::Unschedulable(m)
            | DqError::WorkerLost(m)
            | DqError::Timeout(m)
            | DqError::Cancelled(m)
            | DqError::Protocol(m)
            | DqError::Arity(m)
            | DqError::Io(m) => m,
        }
    }

    /// Wire encoding: `{"kind": "...", "msg": "..."}` — the payload the
    /// RPC envelope carries in its `error` field.
    pub fn to_wire(&self) -> Value {
        Value::obj().with("kind", self.kind()).with("msg", self.message())
    }

    /// Decode the wire encoding. A bare string (a legacy / foreign
    /// error) decodes as [`DqError::Protocol`] so nothing is dropped.
    pub fn from_wire(v: &Value) -> DqError {
        if let Some(s) = v.as_str() {
            return DqError::Protocol(s.to_string());
        }
        let msg = v.get("msg").and_then(Value::as_str).unwrap_or("").to_string();
        match v.get("kind").and_then(Value::as_str) {
            Some("unschedulable") => DqError::Unschedulable(msg),
            Some("worker_lost") => DqError::WorkerLost(msg),
            Some("timeout") => DqError::Timeout(msg),
            Some("cancelled") => DqError::Cancelled(msg),
            Some("protocol") => DqError::Protocol(msg),
            Some("arity") => DqError::Arity(msg),
            Some("io") => DqError::Io(msg),
            _ => DqError::Protocol(format!("undecodable error payload: {v}")),
        }
    }
}

impl std::fmt::Display for DqError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.kind(), self.message())
    }
}

impl std::error::Error for DqError {}

impl From<std::io::Error> for DqError {
    fn from(e: std::io::Error) -> DqError {
        DqError::Io(e.to_string())
    }
}

/// Stringly-typed errors entering the typed boundary (e.g. from
/// [`crate::wire::Value`] field accessors or `QuClassiConfig::new`) are
/// wire/shape problems by construction — classify them as `Protocol`.
impl From<String> for DqError {
    fn from(msg: String) -> DqError {
        DqError::Protocol(msg)
    }
}

impl From<&str> for DqError {
    fn from(msg: &str) -> DqError {
        DqError::Protocol(msg.to_string())
    }
}

/// Interop with the remaining `Result<_, String>` layers (CLI, model
/// internals): `?` flattens a `DqError` into its display form.
impl From<DqError> for String {
    fn from(e: DqError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_variants() -> Vec<DqError> {
        vec![
            DqError::Unschedulable("needs 9 qubits".into()),
            DqError::WorkerLost("w3 evicted".into()),
            DqError::Timeout("bank 7 deadline".into()),
            DqError::Cancelled("bank 7 cancelled".into()),
            DqError::Protocol("short fids".into()),
            DqError::Arity("theta len 3 != 4".into()),
            DqError::Io("connection reset".into()),
        ]
    }

    #[test]
    fn wire_round_trip_preserves_kind_and_message() {
        for e in all_variants() {
            let back = DqError::from_wire(&e.to_wire());
            assert_eq!(e, back, "round trip of {e}");
        }
    }

    #[test]
    fn json_round_trip_through_serializer() {
        for e in all_variants() {
            let text = crate::wire::json::to_string(&e.to_wire());
            let parsed = crate::wire::json::parse(&text).unwrap();
            assert_eq!(DqError::from_wire(&parsed), e);
        }
    }

    #[test]
    fn legacy_string_errors_decode_as_protocol() {
        let v = Value::Str("something broke".into());
        assert_eq!(DqError::from_wire(&v), DqError::Protocol("something broke".into()));
    }

    #[test]
    fn unknown_kind_degrades_to_protocol() {
        let v = Value::obj().with("kind", "quantum_decoherence").with("msg", "oops");
        assert!(matches!(DqError::from_wire(&v), DqError::Protocol(_)));
    }

    #[test]
    fn display_includes_kind() {
        let e = DqError::Timeout("bank 3".into());
        assert_eq!(e.to_string(), "timeout: bank 3");
        let s: String = e.into();
        assert!(s.contains("timeout"));
    }
}
