//! # DQuLearn
//!
//! Reproduction of *"Distributed Quantum Learning with co-Management in a
//! Multi-tenant Quantum System"* (D'Onofrio et al., CS.DC 2023) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The crate is organized bottom-up (see DESIGN.md for the inventory):
//!
//! * substrates: [`util`], [`wire`], [`error`], [`net`], [`cli`], [`benchlib`], [`testlib`]
//! * quantum: [`qsim`] (from-scratch statevector simulator), [`circuit`]
//!   (IR + QuClassi builder + parameter-shift banks)
//! * learning: [`data`], [`model`], [`baseline`]
//! * system (the paper's contribution): [`coordinator`] (co-Manager),
//!   [`worker`], [`runtime`] (PJRT artifact engine), [`cluster`]
//! * evaluation: [`des`] (discrete-event simulator), [`env`] (cloud
//!   models), [`metrics`]

pub mod util;
#[macro_use]
pub mod wire;
pub mod error;
pub mod baseline;
pub mod benchlib;
pub mod circuit;
pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod des;
pub mod env;
pub mod metrics;
pub mod model;
pub mod net;
pub mod qsim;
pub mod runtime;
pub mod testlib;
pub mod worker;

pub use error::DqError;
