//! Capped exponential backoff with deterministic jitter.
//!
//! Transient network blips (a worker restarting, a listener backlog
//! burst) used to surface immediately as [`DqError::Io`] from
//! `RpcClient::connect` — one refused `connect(2)` and the dial failed.
//! Every reconnecting call site now retries through [`retry`]: delays
//! grow `base·2ⁿ` up to `cap`, and each delay is jittered into
//! `[50%, 100%]` of its nominal value so a fleet of workers restarting
//! together doesn't reconnect in lockstep (the thundering-herd rule).
//!
//! Jitter is driven by the crate's own [`Rng`] (std-only, no `rand`
//! dependency), seeded per call site from a process-global counter —
//! deterministic enough to test, distinct enough to decorrelate.
//!
//! [`DqError::Io`]: crate::error::DqError::Io

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::util::Rng;

/// Capped exponential backoff schedule with multiplicative jitter.
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    /// A schedule starting at `base`, doubling per attempt, capped at
    /// `cap`. `seed` drives the jitter stream (see [`auto_seed`]).
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Backoff {
        Backoff { base, cap, attempt: 0, rng: Rng::new(seed) }
    }

    /// The next delay to sleep: `min(cap, base·2ⁿ)` jittered into
    /// `[50%, 100%]`. Advances the attempt counter.
    pub fn next_delay(&mut self) -> Duration {
        // 2^16 * any sane base already exceeds any sane cap; clamping the
        // exponent keeps the shift well-defined without saturating math.
        let nominal = self.base.saturating_mul(1u32 << self.attempt.min(16)).min(self.cap);
        self.attempt = self.attempt.saturating_add(1);
        nominal.mul_f64(0.5 + 0.5 * self.rng.f64())
    }

    /// Restart the schedule (e.g. after a successful reconnect).
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

/// A fresh jitter seed: a process-global Weyl sequence, so concurrent
/// dialers get decorrelated jitter without any shared clock or `rand`.
pub fn auto_seed() -> u64 {
    static SEED: AtomicU64 = AtomicU64::new(0x9E37_79B9_7F4A_7C15);
    SEED.fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed)
}

/// Retry `op` under a capped exponential backoff until it succeeds or
/// `timeout` elapses; the last error is returned. The first attempt is
/// immediate; sleeps never overshoot the deadline.
pub fn retry<T, E>(
    timeout: Duration,
    base: Duration,
    cap: Duration,
    mut op: impl FnMut() -> Result<T, E>,
) -> Result<T, E> {
    let deadline = Instant::now() + timeout;
    let mut backoff = Backoff::new(base, cap, auto_seed());
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    return Err(e);
                }
                std::thread::sleep(backoff.next_delay().min(deadline - now));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_grow_then_cap() {
        let mut b = Backoff::new(Duration::from_millis(10), Duration::from_millis(100), 7);
        let mut prev_nominal_hit_cap = false;
        for i in 0..12 {
            let d = b.next_delay();
            // jitter keeps every delay inside [50%, 100%] of the nominal
            let nominal = Duration::from_millis(10)
                .saturating_mul(1u32 << i.min(16))
                .min(Duration::from_millis(100));
            assert!(d <= nominal, "delay {d:?} above nominal {nominal:?}");
            assert!(d >= nominal.mul_f64(0.5), "delay {d:?} under half of {nominal:?}");
            prev_nominal_hit_cap |= nominal == Duration::from_millis(100);
        }
        assert!(prev_nominal_hit_cap, "schedule never reached its cap");
    }

    #[test]
    fn jitter_streams_differ_across_seeds() {
        let mut a = Backoff::new(Duration::from_millis(10), Duration::from_secs(1), 1);
        let mut b = Backoff::new(Duration::from_millis(10), Duration::from_secs(1), 2);
        let differs = (0..8).any(|_| a.next_delay() != b.next_delay());
        assert!(differs, "two seeds produced identical jitter streams");
    }

    #[test]
    fn reset_restarts_the_schedule() {
        let mut b = Backoff::new(Duration::from_millis(10), Duration::from_secs(10), 3);
        for _ in 0..6 {
            b.next_delay();
        }
        b.reset();
        assert!(b.next_delay() <= Duration::from_millis(10));
    }

    #[test]
    fn retry_returns_first_success() {
        let mut calls = 0;
        let out: Result<u32, &str> = retry(
            Duration::from_secs(5),
            Duration::from_millis(1),
            Duration::from_millis(2),
            || {
                calls += 1;
                if calls < 3 {
                    Err("not yet")
                } else {
                    Ok(42)
                }
            },
        );
        assert_eq!(out, Ok(42));
        assert_eq!(calls, 3);
    }

    #[test]
    fn retry_surfaces_last_error_at_deadline() {
        let out: Result<(), String> = retry(
            Duration::from_millis(5),
            Duration::from_millis(1),
            Duration::from_millis(2),
            || Err("still down".to_string()),
        );
        assert_eq!(out, Err("still down".to_string()));
    }
}
