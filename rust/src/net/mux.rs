//! `net/mux` — the async multiplexed cluster plane (DESIGN.md §17).
//!
//! One event-loop thread owns every worker socket in nonblocking mode
//! and multiplexes hundreds of in-flight RPCs over them; completions
//! run on one dedicated runner thread. Compare the JSON plane
//! (`net/rpc`), which parks one OS thread per in-flight call.
//!
//! **Readiness model (why std-only works).** The crate is
//! dependency-free, so there is no `epoll`/`kqueue`. Instead the loop
//! does a nonblocking readiness *scan*: each iteration drains the
//! command queue, then for every connection flushes as much of the
//! write queue as the socket accepts, reads whatever bytes are
//! available, and parses complete frames out of the per-connection
//! buffer. When an iteration makes no progress the loop parks on a
//! condvar for 1 ms (command submitters notify it), so an idle plane
//! costs ~1k wakeups/s on one thread — and a busy plane never sleeps.
//!
//! **Correlation ids.** Requests are tagged with a per-connection
//! monotonically increasing correlation id; responses echo it. That is
//! the whole multiplexing trick: any number of requests can be in
//! flight per socket, and responses may arrive in any order.
//!
//! **Frame layout** (after the handshake, both directions):
//!
//! ```text
//! [u32 body_len LE][u32 crc32 LE][body]
//! body := kind:u8, corr:varint, (op:varint if kind==REQ), payload...
//! kind := 0 REQ | 1 OK | 2 ERR | 3 PING | 4 PONG
//! ```
//!
//! The crc32 (same polynomial as the journal) makes corruption —
//! including single-bit flips — a deterministic connection-fatal
//! `Protocol` error instead of a misparse.
//!
//! **Handshake / version negotiation.** A connecting peer sends
//! `b"DQMX"` + version + feature bits; the server echoes the same
//! shape and both sides speak `min(version)` with the feature
//! intersection. The magic doubles as the downgrade detector: an old
//! JSON-only server reads `b"DQMX"` as a big-endian frame length
//! (≈1.1 GB > `MAX_FRAME`) and closes, the dialer sees EOF instead of
//! a hello, and falls back to the JSON channel — old workers interop
//! without any out-of-band capability registry. Symmetrically, the
//! upgraded JSON server (`RpcServer::serve_bin`) sniffs the first four
//! bytes of each accepted connection and routes magic to a binary
//! session, anything else to the JSON loop.
//!
//! **Backpressure.** Each connection has a bounded write queue and a
//! bounded pending-request map; a request that would exceed either
//! fails *immediately* with `DqError::Io("mux backpressure…")` rather
//! than queueing unboundedly — the co-Manager's outbox requeues the
//! batch, which is exactly the load-shedding path it already has.
//!
//! **Liveness.** The loop pings a quiet connection every
//! `ping_interval`; a connection silent past `idle_timeout` is torn
//! down and every pending request on it fails `WorkerLost` — the same
//! error the heartbeat evictor produces, so the manager's existing
//! requeue/eviction path absorbs transport death with no new states.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backoff;
use super::frame::MAX_FRAME;
use crate::coordinator::journal::crc32;
use crate::error::DqError;
use crate::wire::bin;

/// Connection-hello magic. Chosen so a legacy JSON peer reads it as an
/// oversized big-endian frame length and closes (see module docs).
pub const MAGIC: [u8; 4] = *b"DQMX";

/// Frame kinds.
pub const KIND_REQ: u8 = 0;
pub const KIND_OK: u8 = 1;
pub const KIND_ERR: u8 = 2;
pub const KIND_PING: u8 = 3;
pub const KIND_PONG: u8 = 4;

/// A binary-plane request handler: interned op id and raw payload in,
/// raw payload (or typed error) out. The worker service and test parks
/// implement this; `wire::bin` owns the payload codecs.
pub trait MuxService: Send + Sync + 'static {
    fn handle(&self, op: u32, payload: &[u8]) -> Result<Vec<u8>, DqError>;
}

impl<F> MuxService for F
where
    F: Fn(u32, &[u8]) -> Result<Vec<u8>, DqError> + Send + Sync + 'static,
{
    fn handle(&self, op: u32, payload: &[u8]) -> Result<Vec<u8>, DqError> {
        self(op, payload)
    }
}

// ---------------------------------------------------------------------------
// transport-thread gauge
// ---------------------------------------------------------------------------

static TRANSPORT_THREADS: AtomicUsize = AtomicUsize::new(0);

/// How many mux transport threads (event loops, completion runners,
/// server parks) are alive right now, process-wide. The 256-worker
/// soak bench asserts this stays ≤ 3 — the whole point of the plane.
pub fn transport_thread_count() -> usize {
    TRANSPORT_THREADS.load(Ordering::SeqCst)
}

struct TransportGuard;

impl TransportGuard {
    fn enter() -> TransportGuard {
        TRANSPORT_THREADS.fetch_add(1, Ordering::SeqCst);
        TransportGuard
    }
}

impl Drop for TransportGuard {
    fn drop(&mut self) {
        TRANSPORT_THREADS.fetch_sub(1, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------------
// frames
// ---------------------------------------------------------------------------

/// One parsed mux frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: u8,
    pub corr: u64,
    /// Interned op id; meaningful only for `KIND_REQ`.
    pub op: u32,
    pub payload: Vec<u8>,
}

/// Encode one frame (checksummed, length-prefixed).
pub fn encode_frame(kind: u8, corr: u64, op: u32, payload: &[u8]) -> Vec<u8> {
    let mut body = Vec::with_capacity(payload.len() + 12);
    body.push(kind);
    bin::put_varint(&mut body, corr);
    if kind == KIND_REQ {
        bin::put_varint(&mut body, u64::from(op));
    }
    body.extend_from_slice(payload);
    let mut frame = Vec::with_capacity(body.len() + 8);
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&crc32(&body).to_le_bytes());
    frame.extend_from_slice(&body);
    frame
}

fn parse_body(body: &[u8]) -> Result<Frame, DqError> {
    let mut c = bin::Cur::new(body);
    let kind = c.take(1)?[0];
    if kind > KIND_PONG {
        return Err(DqError::Protocol(format!("mux: unknown frame kind {kind}")));
    }
    let corr = c.take_varint()?;
    let op = if kind == KIND_REQ {
        u32::try_from(c.take_varint()?)
            .map_err(|_| DqError::Protocol("mux: op id exceeds u32".into()))?
    } else {
        0
    };
    let n = c.remaining();
    let payload = c.take(n)?.to_vec();
    Ok(Frame { kind, corr, op, payload })
}

/// Try to split one frame off the front of a receive buffer.
/// `Ok(None)` means "need more bytes"; any structural violation
/// (oversized length, checksum mismatch, bad body) is connection-fatal.
pub fn take_frame(buf: &mut Vec<u8>) -> Result<Option<Frame>, DqError> {
    if buf.len() < 8 {
        return Ok(None);
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if len > MAX_FRAME {
        return Err(DqError::Protocol(format!("mux: frame of {len} bytes exceeds cap")));
    }
    let total = 8 + len as usize;
    if buf.len() < total {
        return Ok(None);
    }
    let crc = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]);
    let frame = {
        let body = &buf[8..total];
        if crc32(body) != crc {
            return Err(DqError::Protocol("mux: frame checksum mismatch".into()));
        }
        parse_body(body)?
    };
    buf.drain(..total);
    Ok(Some(frame))
}

fn hello() -> [u8; 6] {
    [MAGIC[0], MAGIC[1], MAGIC[2], MAGIC[3], bin::BIN_VERSION, bin::FEAT_BIN_EXECUTE]
}

/// Outcome of the connect handshake.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Negotiated {
    /// `min(our version, peer version)`; never 0 on success.
    pub version: u8,
    /// Intersection of the feature bit sets.
    pub features: u8,
}

fn negotiate(peer_version: u8, peer_features: u8) -> Result<Negotiated, DqError> {
    let version = peer_version.min(bin::BIN_VERSION);
    if version == 0 {
        return Err(DqError::Protocol("mux: peer negotiated version 0".into()));
    }
    Ok(Negotiated { version, features: peer_features & bin::FEAT_BIN_EXECUTE })
}

/// Run the dialing side of the handshake on a blocking stream. An EOF
/// here is the legacy-JSON-server signature (it read our magic as an
/// oversized frame and closed) — callers treat any error as "fall back
/// to the JSON channel".
pub fn client_handshake(stream: &mut TcpStream, timeout: Duration) -> Result<Negotiated, DqError> {
    stream.set_read_timeout(Some(timeout))?;
    stream.write_all(&hello())?;
    stream.flush()?;
    let mut reply = [0u8; 6];
    stream.read_exact(&mut reply).map_err(|e| {
        DqError::Io(format!("mux handshake got no hello (JSON-only peer?): {e}"))
    })?;
    if reply[..4] != MAGIC {
        return Err(DqError::Protocol("mux: bad handshake magic from peer".into()));
    }
    let negotiated = negotiate(reply[4], reply[5])?;
    stream.set_read_timeout(None)?;
    Ok(negotiated)
}

// ---------------------------------------------------------------------------
// poll-tolerant exact reads (shared with net/rpc's sniffing loop)
// ---------------------------------------------------------------------------

/// Outcome of [`poll_read_exact`].
pub(crate) enum PollRead {
    /// Buffer fully read.
    Done,
    /// Clean EOF before the first byte.
    Eof,
    /// The stop flag was raised while waiting.
    Stopped,
}

/// Read exactly `buf.len()` bytes, tolerating read-timeout polls
/// (`WouldBlock`/`TimedOut`) without losing partial data — unlike
/// `read_exact`, whose buffer state is unspecified on error. EOF after
/// partial data is an error (a torn frame), EOF at offset 0 is clean.
pub(crate) fn poll_read_exact(
    r: &mut impl Read,
    buf: &mut [u8],
    stop: &AtomicBool,
) -> std::io::Result<PollRead> {
    let mut filled = 0;
    while filled < buf.len() {
        if stop.load(Ordering::Relaxed) {
            return Ok(PollRead::Stopped);
        }
        match r.read(&mut buf[filled..]) {
            Ok(0) if filled == 0 => return Ok(PollRead::Eof),
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof inside a frame",
                ))
            }
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e),
        }
    }
    Ok(PollRead::Done)
}

/// Serve one *binary* session on a thread-per-connection server
/// (`RpcServer::serve_bin` routes here after sniffing the magic, which
/// has already been consumed). Requests dispatch inline; malformed
/// frames close the connection.
pub(crate) fn serve_bin_connection(
    mut reader: BufReader<TcpStream>,
    mut writer: BufWriter<TcpStream>,
    service: Arc<dyn MuxService>,
    stop: Arc<AtomicBool>,
) {
    // Finish the handshake: 2 bytes of version+features follow the magic.
    let mut rest = [0u8; 2];
    if !matches!(poll_read_exact(&mut reader, &mut rest, &stop), Ok(PollRead::Done)) {
        return;
    }
    if negotiate(rest[0], rest[1]).is_err() {
        return;
    }
    if writer.write_all(&hello()).and_then(|_| writer.flush()).is_err() {
        return;
    }
    while !stop.load(Ordering::Relaxed) {
        let mut header = [0u8; 8];
        if !matches!(poll_read_exact(&mut reader, &mut header, &stop), Ok(PollRead::Done)) {
            return;
        }
        let len = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
        let crc = u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
        if len > MAX_FRAME {
            return;
        }
        let mut body = vec![0u8; len as usize];
        if !matches!(poll_read_exact(&mut reader, &mut body, &stop), Ok(PollRead::Done)) {
            return;
        }
        if crc32(&body) != crc {
            return;
        }
        let frame = match parse_body(&body) {
            Ok(f) => f,
            Err(_) => return,
        };
        let out = match frame.kind {
            KIND_PING => encode_frame(KIND_PONG, frame.corr, 0, &[]),
            KIND_REQ => match service.handle(frame.op, &frame.payload) {
                Ok(p) => encode_frame(KIND_OK, frame.corr, 0, &p),
                Err(e) => encode_frame(KIND_ERR, frame.corr, 0, &bin::encode_error(&e)),
            },
            _ => return, // only a dialer sends OK/ERR/PONG
        };
        if writer.write_all(&out).and_then(|_| writer.flush()).is_err() {
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// the multiplexer (dialing side: the co-Manager)
// ---------------------------------------------------------------------------

/// Tuning knobs for a [`Mux`].
#[derive(Debug, Clone)]
pub struct MuxConfig {
    /// Ping a connection with no inbound traffic for this long.
    pub ping_interval: Duration,
    /// Tear a connection down (failing its pending requests
    /// `WorkerLost`) after this long without any inbound traffic.
    pub idle_timeout: Duration,
    /// Per-connection cap on in-flight requests (backpressure).
    pub max_inflight: usize,
    /// Per-connection cap on queued unwritten bytes (backpressure).
    pub write_high_water: usize,
    /// Dial budget: TCP connect retries (capped backoff) + handshake.
    pub connect_timeout: Duration,
}

impl Default for MuxConfig {
    fn default() -> MuxConfig {
        MuxConfig {
            ping_interval: Duration::from_secs(10),
            idle_timeout: Duration::from_secs(60),
            max_inflight: 1024,
            write_high_water: 8 << 20,
            connect_timeout: Duration::from_secs(5),
        }
    }
}

/// A connection handle returned by [`Mux::connect`].
#[derive(Debug, Clone, Copy)]
pub struct MuxConn {
    pub id: u64,
    pub negotiated: Negotiated,
}

type Callback = Box<dyn FnOnce(Result<Vec<u8>, DqError>) + Send + 'static>;

struct Completion {
    cb: Callback,
    res: Result<Vec<u8>, DqError>,
}

enum Cmd {
    Register { id: u64, stream: TcpStream },
    Request { conn: u64, op: u32, payload: Vec<u8>, done: Callback },
}

struct Shared {
    cmds: Mutex<Vec<Cmd>>,
    cv: Condvar,
    stop: AtomicBool,
    /// Connections the loop has torn down: requests fail fast.
    dead: Mutex<std::collections::HashSet<u64>>,
}

/// The multiplexer: two threads total (event loop + completion runner)
/// regardless of connection or in-flight-request count.
pub struct Mux {
    shared: Arc<Shared>,
    cfg: MuxConfig,
    next_conn: AtomicU64,
    loop_thread: Mutex<Option<JoinHandle<()>>>,
    runner_thread: Mutex<Option<JoinHandle<()>>>,
}

impl Mux {
    /// Spawn the event-loop and completion-runner threads.
    pub fn new(cfg: MuxConfig) -> Arc<Mux> {
        let shared = Arc::new(Shared {
            cmds: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            dead: Mutex::new(std::collections::HashSet::new()),
        });
        let (done_tx, done_rx) = mpsc::channel::<Completion>();
        let shared2 = shared.clone();
        let cfg2 = cfg.clone();
        let loop_thread = std::thread::Builder::new()
            .name("mux-loop".into())
            .spawn(move || run_event_loop(shared2, cfg2, done_tx))
            .expect("spawn mux-loop");
        let runner_thread = std::thread::Builder::new()
            .name("mux-done".into())
            .spawn(move || {
                let _gauge = TransportGuard::enter();
                while let Ok(c) = done_rx.recv() {
                    (c.cb)(c.res);
                }
            })
            .expect("spawn mux-done");
        Arc::new(Mux {
            shared,
            cfg,
            next_conn: AtomicU64::new(1),
            loop_thread: Mutex::new(Some(loop_thread)),
            runner_thread: Mutex::new(Some(runner_thread)),
        })
    }

    /// Dial a peer (TCP connect under capped backoff + jitter, then the
    /// version handshake) and hand the socket to the event loop. Errors
    /// mean "this peer does not speak mux" — callers fall back to JSON.
    pub fn connect<A: ToSocketAddrs + Clone>(&self, addr: A) -> Result<MuxConn, DqError> {
        if self.shared.stop.load(Ordering::Relaxed) {
            return Err(DqError::Cancelled("mux is shut down".into()));
        }
        let mut stream = backoff::retry(
            self.cfg.connect_timeout,
            Duration::from_millis(10),
            Duration::from_millis(500),
            || TcpStream::connect(addr.clone()),
        )
        .map_err(|e| DqError::Io(format!("mux connect failed: {e}")))?;
        stream.set_nodelay(true).map_err(|e| DqError::Io(e.to_string()))?;
        let negotiated = client_handshake(&mut stream, self.cfg.connect_timeout)?;
        stream.set_nonblocking(true).map_err(|e| DqError::Io(e.to_string()))?;
        let id = self.next_conn.fetch_add(1, Ordering::Relaxed);
        self.push(Cmd::Register { id, stream });
        Ok(MuxConn { id, negotiated })
    }

    /// Enqueue-and-notify: hand a request to the event loop; `done`
    /// runs on the completion-runner thread (or inline, if the plane is
    /// already stopped). Never blocks on the network.
    pub fn request(&self, conn: u64, op: u32, payload: Vec<u8>, done: Callback) {
        if self.shared.stop.load(Ordering::Relaxed) {
            done(Err(DqError::Cancelled("mux is shut down".into())));
            return;
        }
        if self.is_dead(conn) {
            done(Err(DqError::WorkerLost(format!("mux connection {conn} is closed"))));
            return;
        }
        self.push(Cmd::Request { conn, op, payload, done });
    }

    /// Blocking convenience over [`Mux::request`].
    pub fn call(&self, conn: u64, op: u32, payload: Vec<u8>) -> Result<Vec<u8>, DqError> {
        let (tx, rx) = mpsc::channel();
        self.request(
            conn,
            op,
            payload,
            Box::new(move |res| {
                let _ = tx.send(res);
            }),
        );
        rx.recv().unwrap_or_else(|_| Err(DqError::Cancelled("mux is shut down".into())))
    }

    /// Has the event loop torn this connection down?
    pub fn is_dead(&self, conn: u64) -> bool {
        self.shared.dead.lock().expect("mux dead set poisoned").contains(&conn)
    }

    /// Stop both threads, failing every pending request `Cancelled`.
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(t) = self.loop_thread.lock().expect("mux join poisoned").take() {
            let _ = t.join();
        }
        if let Some(t) = self.runner_thread.lock().expect("mux join poisoned").take() {
            let _ = t.join();
        }
    }

    fn push(&self, cmd: Cmd) {
        self.shared.cmds.lock().expect("mux cmd queue poisoned").push(cmd);
        self.shared.cv.notify_all();
    }
}

impl Drop for Mux {
    fn drop(&mut self) {
        self.shutdown();
    }
}

struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    woff: usize,
    pending: HashMap<u64, Callback>,
    next_corr: u64,
    last_rx: Instant,
    last_ping: Instant,
}

impl Conn {
    fn queued_bytes(&self) -> usize {
        self.wbuf.len() - self.woff
    }
}

fn run_event_loop(shared: Arc<Shared>, cfg: MuxConfig, done: mpsc::Sender<Completion>) {
    let _gauge = TransportGuard::enter();
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut scratch = vec![0u8; 64 * 1024];
    let mut progress = true;
    let complete = |cb: Callback, res: Result<Vec<u8>, DqError>| {
        let _ = done.send(Completion { cb, res });
    };
    loop {
        // Drain commands; park 1 ms only when the last scan was idle.
        let cmds: Vec<Cmd> = {
            let mut q = shared.cmds.lock().expect("mux cmd queue poisoned");
            if q.is_empty() && !progress && !shared.stop.load(Ordering::Relaxed) {
                q = shared.cv.wait_timeout(q, Duration::from_millis(1)).expect("mux cv").0;
            }
            std::mem::take(&mut *q)
        };
        if shared.stop.load(Ordering::Relaxed) {
            for (_, conn) in conns.drain() {
                for (_, cb) in conn.pending {
                    complete(cb, Err(DqError::Cancelled("mux is shut down".into())));
                }
            }
            for cmd in cmds {
                if let Cmd::Request { done: cb, .. } = cmd {
                    complete(cb, Err(DqError::Cancelled("mux is shut down".into())));
                }
            }
            return;
        }
        progress = false;
        let now = Instant::now();
        for cmd in cmds {
            progress = true;
            match cmd {
                Cmd::Register { id, stream } => {
                    conns.insert(
                        id,
                        Conn {
                            stream,
                            rbuf: Vec::new(),
                            wbuf: Vec::new(),
                            woff: 0,
                            pending: HashMap::new(),
                            next_corr: 1,
                            last_rx: now,
                            last_ping: now,
                        },
                    );
                }
                Cmd::Request { conn, op, payload, done: cb } => match conns.get_mut(&conn) {
                    None => complete(
                        cb,
                        Err(DqError::WorkerLost(format!("mux connection {conn} is closed"))),
                    ),
                    Some(c) if c.pending.len() >= cfg.max_inflight => complete(
                        cb,
                        Err(DqError::Io(format!(
                            "mux backpressure: {} requests in flight on connection {conn}",
                            c.pending.len()
                        ))),
                    ),
                    Some(c) if c.queued_bytes() > cfg.write_high_water => complete(
                        cb,
                        Err(DqError::Io(format!(
                            "mux backpressure: {} bytes queued on connection {conn}",
                            c.queued_bytes()
                        ))),
                    ),
                    Some(c) => {
                        let corr = c.next_corr;
                        c.next_corr += 1;
                        c.pending.insert(corr, cb);
                        c.wbuf.extend_from_slice(&encode_frame(KIND_REQ, corr, op, &payload));
                    }
                },
            }
        }
        let mut doomed: Vec<(u64, DqError)> = Vec::new();
        for (&id, conn) in conns.iter_mut() {
            // 1. flush the write queue as far as the socket accepts
            while conn.woff < conn.wbuf.len() {
                match conn.stream.write(&conn.wbuf[conn.woff..]) {
                    Ok(0) => {
                        doomed.push((id, DqError::WorkerLost("mux write end closed".into())));
                        break;
                    }
                    Ok(n) => {
                        conn.woff += n;
                        progress = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        doomed.push((id, DqError::WorkerLost(format!("mux write failed: {e}"))));
                        break;
                    }
                }
            }
            if conn.woff == conn.wbuf.len() {
                conn.wbuf.clear();
                conn.woff = 0;
            } else if conn.woff > 64 * 1024 {
                conn.wbuf.drain(..conn.woff);
                conn.woff = 0;
            }
            if doomed.last().is_some_and(|(d, _)| *d == id) {
                continue;
            }
            // 2. read whatever is available
            loop {
                match conn.stream.read(&mut scratch) {
                    Ok(0) => {
                        doomed.push((id, DqError::WorkerLost("mux peer closed".into())));
                        break;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&scratch[..n]);
                        conn.last_rx = now;
                        progress = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        doomed.push((id, DqError::WorkerLost(format!("mux read failed: {e}"))));
                        break;
                    }
                }
            }
            if doomed.last().is_some_and(|(d, _)| *d == id) {
                continue;
            }
            // 3. complete whole frames
            loop {
                match take_frame(&mut conn.rbuf) {
                    Ok(None) => break,
                    Ok(Some(f)) => match f.kind {
                        KIND_OK => {
                            if let Some(cb) = conn.pending.remove(&f.corr) {
                                complete(cb, Ok(f.payload));
                            }
                        }
                        KIND_ERR => {
                            if let Some(cb) = conn.pending.remove(&f.corr) {
                                let e = bin::decode_error(&f.payload).unwrap_or_else(|e| e);
                                complete(cb, Err(e));
                            }
                        }
                        KIND_PONG => {}
                        _ => {
                            doomed.push((
                                id,
                                DqError::Protocol(format!(
                                    "mux: unexpected frame kind {} from responder",
                                    f.kind
                                )),
                            ));
                            break;
                        }
                    },
                    Err(e) => {
                        doomed.push((id, e));
                        break;
                    }
                }
            }
            if doomed.last().is_some_and(|(d, _)| *d == id) {
                continue;
            }
            // 4. liveness: ping quiet peers, doom silent ones
            let quiet = now.saturating_duration_since(conn.last_rx);
            if quiet > cfg.idle_timeout {
                doomed.push((
                    id,
                    DqError::WorkerLost(format!(
                        "mux idle timeout: no traffic for {:.1}s",
                        quiet.as_secs_f64()
                    )),
                ));
            } else if quiet >= cfg.ping_interval
                && now.saturating_duration_since(conn.last_ping) >= cfg.ping_interval
            {
                conn.wbuf.extend_from_slice(&encode_frame(KIND_PING, 0, 0, &[]));
                conn.last_ping = now;
            }
        }
        for (id, err) in doomed {
            if let Some(conn) = conns.remove(&id) {
                crate::log_warn!("mux", "connection {id} torn down: {err}");
                shared.dead.lock().expect("mux dead set poisoned").insert(id);
                for (_, cb) in conn.pending {
                    complete(cb, Err(err.clone()));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// the single-threaded server park (answering side at scale)
// ---------------------------------------------------------------------------

/// A binary-only server that serves *all* accepted connections from one
/// readiness-scan thread — the answering-side twin of [`Mux`]. The
/// 256-worker soak bench parks every worker connection here, which is
/// what keeps the whole transport at 3 threads. Handlers run inline on
/// the loop thread, so they must be fast (decode + compute + encode).
pub struct MuxServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl MuxServer {
    /// Bind (port 0 for ephemeral) and start the serve loop.
    pub fn serve<A: ToSocketAddrs>(
        addr: A,
        service: Arc<dyn MuxService>,
    ) -> std::io::Result<MuxServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::Builder::new()
            .name("mux-server".into())
            .spawn(move || run_server_loop(listener, service, stop2))
            .expect("spawn mux-server");
        Ok(MuxServer { addr: local, stop, thread: Some(thread) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop and join the serve loop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MuxServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

struct ServerConn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    woff: usize,
    greeted: bool,
    alive: bool,
}

fn run_server_loop(listener: TcpListener, service: Arc<dyn MuxService>, stop: Arc<AtomicBool>) {
    let _gauge = TransportGuard::enter();
    let mut conns: Vec<ServerConn> = Vec::new();
    let mut scratch = vec![0u8; 64 * 1024];
    let mut accepting = true;
    while !stop.load(Ordering::Relaxed) {
        let mut progress = false;
        while accepting {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let _ = stream.set_nonblocking(true);
                    let _ = stream.set_nodelay(true);
                    conns.push(ServerConn {
                        stream,
                        rbuf: Vec::new(),
                        wbuf: Vec::new(),
                        woff: 0,
                        greeted: false,
                        alive: true,
                    });
                    progress = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    // Fatal listener error: stop accepting, keep serving
                    // the connections that already exist.
                    crate::log_warn!("mux", "mux-server accept failed fatally: {e}");
                    accepting = false;
                }
            }
        }
        for conn in conns.iter_mut() {
            progress |= serve_one(conn, &service, &mut scratch);
        }
        conns.retain(|c| c.alive);
        if !progress {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
}

/// One readiness pass over one server-side connection; returns whether
/// any bytes moved.
fn serve_one(conn: &mut ServerConn, service: &Arc<dyn MuxService>, scratch: &mut [u8]) -> bool {
    let mut progress = false;
    // flush pending responses
    while conn.woff < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.woff..]) {
            Ok(0) => {
                conn.alive = false;
                return progress;
            }
            Ok(n) => {
                conn.woff += n;
                progress = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                conn.alive = false;
                return progress;
            }
        }
    }
    if conn.woff == conn.wbuf.len() {
        conn.wbuf.clear();
        conn.woff = 0;
    }
    // read available bytes
    loop {
        match conn.stream.read(scratch) {
            Ok(0) => {
                conn.alive = false;
                return progress;
            }
            Ok(n) => {
                conn.rbuf.extend_from_slice(&scratch[..n]);
                progress = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                conn.alive = false;
                return progress;
            }
        }
    }
    // handshake, then serve complete frames
    if !conn.greeted {
        if conn.rbuf.len() < 6 {
            return progress;
        }
        if conn.rbuf[..4] != MAGIC || negotiate(conn.rbuf[4], conn.rbuf[5]).is_err() {
            conn.alive = false;
            return progress;
        }
        conn.rbuf.drain(..6);
        conn.wbuf.extend_from_slice(&hello());
        conn.greeted = true;
        progress = true;
    }
    loop {
        match take_frame(&mut conn.rbuf) {
            Ok(None) => break,
            Ok(Some(f)) => {
                progress = true;
                let out = match f.kind {
                    KIND_PING => encode_frame(KIND_PONG, f.corr, 0, &[]),
                    KIND_REQ => match service.handle(f.op, &f.payload) {
                        Ok(p) => encode_frame(KIND_OK, f.corr, 0, &p),
                        Err(e) => encode_frame(KIND_ERR, f.corr, 0, &bin::encode_error(&e)),
                    },
                    _ => {
                        conn.alive = false;
                        return progress;
                    }
                };
                conn.wbuf.extend_from_slice(&out);
            }
            Err(_) => {
                conn.alive = false;
                return progress;
            }
        }
    }
    progress
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_service() -> Arc<dyn MuxService> {
        Arc::new(|op: u32, payload: &[u8]| -> Result<Vec<u8>, DqError> {
            match op {
                7 => Ok(payload.to_vec()),
                8 => Err(DqError::Cancelled("op 8 always cancels".into())),
                _ => Err(DqError::Protocol(format!("unknown op {op}"))),
            }
        })
    }

    #[test]
    fn frame_round_trips() {
        let mut buf = encode_frame(KIND_REQ, 42, 7, b"hello");
        let f = take_frame(&mut buf).unwrap().unwrap();
        assert_eq!(f, Frame { kind: KIND_REQ, corr: 42, op: 7, payload: b"hello".to_vec() });
        assert!(buf.is_empty());
    }

    #[test]
    fn partial_frame_wants_more_bytes() {
        let full = encode_frame(KIND_OK, 1, 0, &[9u8; 100]);
        for cut in 0..full.len() {
            let mut partial = full[..cut].to_vec();
            assert!(take_frame(&mut partial).unwrap().is_none(), "cut at {cut}");
        }
    }

    #[test]
    fn bit_flips_are_rejected() {
        let full = encode_frame(KIND_OK, 3, 0, b"payload bytes");
        // flip every bit of the checksummed region (crc + body)
        for byte in 4..full.len() {
            for bit in 0..8 {
                let mut corrupt = full.clone();
                corrupt[byte] ^= 1 << bit;
                assert!(
                    take_frame(&mut corrupt).is_err(),
                    "flip at byte {byte} bit {bit} went undetected"
                );
            }
        }
    }

    #[test]
    fn call_round_trips_over_server_park() {
        let server = MuxServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let mux = Mux::new(MuxConfig::default());
        let conn = mux.connect(server.local_addr()).unwrap();
        assert_eq!(conn.negotiated.version, bin::BIN_VERSION);
        let out = mux.call(conn.id, 7, b"ping pong".to_vec()).unwrap();
        assert_eq!(out, b"ping pong");
        assert!(matches!(mux.call(conn.id, 8, vec![]), Err(DqError::Cancelled(_))));
    }

    #[test]
    fn shutdown_cancels_pending_and_rejects_new() {
        let mux = Mux::new(MuxConfig::default());
        mux.shutdown();
        assert!(matches!(mux.call(1, 7, vec![]), Err(DqError::Cancelled(_))));
    }
}
