//! `net/mux` — the async multiplexed cluster plane (DESIGN.md §17, §19).
//!
//! One event-loop thread owns every worker socket in nonblocking mode
//! and multiplexes hundreds of in-flight RPCs over them; completions
//! run on one dedicated runner thread. Compare the JSON plane
//! (`net/rpc`), which parks one OS thread per in-flight call.
//!
//! **Readiness model (why std-only works).** The crate is
//! dependency-free, so there is no `epoll`/`kqueue`. Instead the loop
//! does a nonblocking readiness *scan*: each iteration drains the
//! command queue, then for every connection flushes as much of the
//! write queue as the socket accepts, reads whatever bytes are
//! available, and parses complete frames out of the per-connection
//! buffer. When an iteration makes no progress the loop parks on a
//! condvar for 1 ms (command submitters notify it), so an idle plane
//! costs ~1k wakeups/s on one thread — and a busy plane never sleeps.
//!
//! **Correlation ids.** Requests are tagged with a per-connection
//! monotonically increasing correlation id; responses echo it. That is
//! the whole multiplexing trick: any number of requests can be in
//! flight per socket, and responses may arrive in any order.
//! Correlation id 0 is reserved for the `attach` exchange.
//!
//! **Frame layout** (after the handshake, both directions):
//!
//! ```text
//! [u32 body_len LE][u32 crc32 LE][body]
//! body := kind:u8, corr:varint, (op:varint if kind==REQ), payload...
//! kind := 0 REQ | 1 OK | 2 ERR | 3 PING | 4 PONG | 5 PUSH
//! ```
//!
//! The crc32 (same polynomial as the journal) makes corruption —
//! including single-bit flips — a deterministic connection-fatal
//! `Protocol` error instead of a misparse.
//!
//! **Handshake / version negotiation.** A connecting peer sends
//! `b"DQMX"` + version + feature bits; the server echoes the same
//! shape and both sides speak `min(version)` with the feature
//! intersection. The magic doubles as the downgrade detector: an old
//! JSON-only server reads `b"DQMX"` as a big-endian frame length
//! (≈1.1 GB > `MAX_FRAME`) and closes, the dialer sees EOF instead of
//! a hello, and falls back to the JSON channel — old workers interop
//! without any out-of-band capability registry. Symmetrically, the
//! upgraded JSON server (`RpcServer::serve_bin`) sniffs the first four
//! bytes of each accepted connection and routes magic to the binary
//! park, anything else to the JSON loop.
//!
//! **Resumable sessions + in-place reconnect (DESIGN.md §19).** When
//! both sides negotiated `FEAT_RESUME`, the dialer's first request is
//! `attach` (correlation id 0) carrying a session token (0 = fresh);
//! the server replies with the token and its *request watermark* — the
//! highest request correlation id it ever received on the session. A
//! connection torn down by a transport fault (read/write error, EOF)
//! is then *revived in place*: a `net/backoff`-driven redialer
//! re-dials, re-handshakes, and re-attaches with the same token, the
//! loop swaps the socket under the same connection id, re-sends only
//! the retained request frames **above** the watermark (TCP delivers
//! requests in corr order, so the watermark is a complete receipt
//! record), and keeps waiting on the rest — their replies were parked
//! in the server-side session and flush after re-attach. Callers never
//! observe the flap: no `WorkerLost`, no re-registration, exactly-once
//! request dispatch. Idle timeouts and protocol violations stay fatal.
//!
//! **Unsolicited pushes.** A streaming request (`subscribe_bank`)
//! leaves its correlation id open: the server pushes `KIND_PUSH`
//! frames on it (bank progress events) and closes it with a final
//! OK/ERR. Pushes ride the session out-queue, so they survive a
//! reconnect like any parked reply.
//!
//! **Backpressure.** Each connection has a bounded write queue and a
//! bounded pending-request map; a request that would exceed either
//! fails *immediately* with `DqError::Io("mux backpressure…")` rather
//! than queueing unboundedly — the co-Manager's outbox requeues the
//! batch, which is exactly the load-shedding path it already has.
//!
//! **Liveness.** The loop pings a quiet connection every
//! `ping_interval`; a connection silent past `idle_timeout` is torn
//! down and every pending request on it fails `WorkerLost` — the same
//! error the heartbeat evictor produces, so the manager's existing
//! requeue/eviction path absorbs transport death with no new states.

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backoff;
use super::frame::MAX_FRAME;
use crate::coordinator::journal::crc32;
use crate::error::DqError;
use crate::wire::bin;

/// Connection-hello magic. Chosen so a legacy JSON peer reads it as an
/// oversized big-endian frame length and closes (see module docs).
pub const MAGIC: [u8; 4] = *b"DQMX";

/// Frame kinds.
pub const KIND_REQ: u8 = 0;
pub const KIND_OK: u8 = 1;
pub const KIND_ERR: u8 = 2;
pub const KIND_PING: u8 = 3;
pub const KIND_PONG: u8 = 4;
/// Unsolicited server→client event on a streaming request's
/// correlation id (`FEAT_PUSH`).
pub const KIND_PUSH: u8 = 5;

// ---------------------------------------------------------------------------
// server-side out-queues and push handles
// ---------------------------------------------------------------------------

/// A connection's (or session's) outbound byte queue. Everything a
/// service produces — inline replies, deferred replies, pushes — lands
/// here; the park loop drains it into the owning connection's write
/// buffer. Because the queue belongs to the *session* (when one is
/// attached), bytes produced while the transport is down are parked,
/// not lost, and flush after an in-place reconnect.
struct OutQueue {
    buf: Mutex<Vec<u8>>,
}

impl OutQueue {
    fn new() -> Arc<OutQueue> {
        Arc::new(OutQueue { buf: Mutex::new(Vec::new()) })
    }

    fn append(&self, bytes: &[u8]) {
        // recover from poison: a panicking service thread must not
        // brick the connection (same discipline as the plan cache)
        self.buf.lock().unwrap_or_else(|e| e.into_inner()).extend_from_slice(bytes);
    }

    /// Move queued bytes into `wbuf`; true when anything moved.
    fn drain_into(&self, wbuf: &mut Vec<u8>) -> bool {
        let mut g = self.buf.lock().unwrap_or_else(|e| e.into_inner());
        if g.is_empty() {
            return false;
        }
        wbuf.extend_from_slice(&g);
        g.clear();
        true
    }

    fn len(&self) -> usize {
        self.buf.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

/// Encode a request's terminal reply frame.
fn reply_frame(corr: u64, res: Result<Vec<u8>, DqError>) -> Vec<u8> {
    match res {
        Ok(p) => encode_frame(KIND_OK, corr, 0, &p),
        Err(e) => encode_frame(KIND_ERR, corr, 0, &bin::encode_error(&e)),
    }
}

/// Handle a streaming service holds to emit events on an open
/// correlation id (see [`MuxService::open_stream`]). Cheap to clone
/// into watcher closures; safe to use from any thread — frames are
/// appended whole, so pushes never interleave mid-frame.
#[derive(Clone)]
pub struct Pusher {
    out: Arc<OutQueue>,
    corr: u64,
}

impl Pusher {
    /// Emit one `KIND_PUSH` event.
    pub fn push(&self, payload: &[u8]) {
        self.out.append(&encode_frame(KIND_PUSH, self.corr, 0, payload));
    }

    /// Close the stream with its terminal OK/ERR reply.
    pub fn finish(&self, res: Result<Vec<u8>, DqError>) {
        self.out.append(&reply_frame(self.corr, res));
    }
}

/// A binary-plane request handler: interned op id and raw payload in,
/// raw payload (or typed error) out. The worker service and the
/// manager's pool service implement this; `wire::bin` owns the payload
/// codecs.
pub trait MuxService: Send + Sync + 'static {
    fn handle(&self, op: u32, payload: &[u8]) -> Result<Vec<u8>, DqError>;

    /// Ops whose `handle` may block (`wait_bank`, worker `execute`):
    /// the park runs them on a transient thread and the reply rides
    /// the session out-queue, so one blocked handler never stalls the
    /// transport. Defaults to "everything is fast, run inline".
    fn defer(&self, _op: u32) -> bool {
        false
    }

    /// Streaming ops: claim the request by returning `Some` — either
    /// `Ok(())` (the stream is open; events flow through `pusher`, and
    /// the service must eventually `finish` it) or an immediate error.
    /// `None` means "not a streaming op", falling through to
    /// [`MuxService::handle`].
    fn open_stream(
        &self,
        _op: u32,
        _payload: &[u8],
        _pusher: Pusher,
    ) -> Option<Result<(), DqError>> {
        None
    }
}

impl<F> MuxService for F
where
    F: Fn(u32, &[u8]) -> Result<Vec<u8>, DqError> + Send + Sync + 'static,
{
    fn handle(&self, op: u32, payload: &[u8]) -> Result<Vec<u8>, DqError> {
        self(op, payload)
    }
}

// ---------------------------------------------------------------------------
// transport-thread gauge
// ---------------------------------------------------------------------------

static TRANSPORT_THREADS: AtomicUsize = AtomicUsize::new(0);

/// How many mux transport threads (event loops, completion runners,
/// server parks) are alive right now, process-wide. The 256-worker
/// soak bench asserts this stays ≤ 3 — the whole point of the plane.
/// Transient helpers (redialers, deferred handlers) are deliberately
/// not transport threads: they exist per event, not per connection.
pub fn transport_thread_count() -> usize {
    TRANSPORT_THREADS.load(Ordering::SeqCst)
}

struct TransportGuard;

impl TransportGuard {
    fn enter() -> TransportGuard {
        TRANSPORT_THREADS.fetch_add(1, Ordering::SeqCst);
        TransportGuard
    }
}

impl Drop for TransportGuard {
    fn drop(&mut self) {
        TRANSPORT_THREADS.fetch_sub(1, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------------
// frames
// ---------------------------------------------------------------------------

/// One parsed mux frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: u8,
    pub corr: u64,
    /// Interned op id; meaningful only for `KIND_REQ`.
    pub op: u32,
    pub payload: Vec<u8>,
}

/// Encode one frame (checksummed, length-prefixed).
pub fn encode_frame(kind: u8, corr: u64, op: u32, payload: &[u8]) -> Vec<u8> {
    let mut body = Vec::with_capacity(payload.len() + 12);
    body.push(kind);
    bin::put_varint(&mut body, corr);
    if kind == KIND_REQ {
        bin::put_varint(&mut body, u64::from(op));
    }
    body.extend_from_slice(payload);
    let mut frame = Vec::with_capacity(body.len() + 8);
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&crc32(&body).to_le_bytes());
    frame.extend_from_slice(&body);
    frame
}

fn parse_body(body: &[u8]) -> Result<Frame, DqError> {
    let mut c = bin::Cur::new(body);
    let kind = c.take(1)?[0];
    if kind > KIND_PUSH {
        return Err(DqError::Protocol(format!("mux: unknown frame kind {kind}")));
    }
    let corr = c.take_varint()?;
    let op = if kind == KIND_REQ {
        u32::try_from(c.take_varint()?)
            .map_err(|_| DqError::Protocol("mux: op id exceeds u32".into()))?
    } else {
        0
    };
    let n = c.remaining();
    let payload = c.take(n)?.to_vec();
    Ok(Frame { kind, corr, op, payload })
}

/// Try to split one frame off the front of a receive buffer.
/// `Ok(None)` means "need more bytes"; any structural violation
/// (oversized length, checksum mismatch, bad body) is connection-fatal.
pub fn take_frame(buf: &mut Vec<u8>) -> Result<Option<Frame>, DqError> {
    if buf.len() < 8 {
        return Ok(None);
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if len > MAX_FRAME {
        return Err(DqError::Protocol(format!("mux: frame of {len} bytes exceeds cap")));
    }
    let total = 8 + len as usize;
    if buf.len() < total {
        return Ok(None);
    }
    let crc = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]);
    let frame = {
        let body = &buf[8..total];
        if crc32(body) != crc {
            return Err(DqError::Protocol("mux: frame checksum mismatch".into()));
        }
        parse_body(body)?
    };
    buf.drain(..total);
    Ok(Some(frame))
}

fn hello() -> [u8; 6] {
    [MAGIC[0], MAGIC[1], MAGIC[2], MAGIC[3], bin::BIN_VERSION, bin::FEAT_ALL]
}

/// Outcome of the connect handshake.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Negotiated {
    /// `min(our version, peer version)`; never 0 on success.
    pub version: u8,
    /// Intersection of the feature bit sets.
    pub features: u8,
}

fn negotiate(peer_version: u8, peer_features: u8) -> Result<Negotiated, DqError> {
    let version = peer_version.min(bin::BIN_VERSION);
    if version == 0 {
        return Err(DqError::Protocol("mux: peer negotiated version 0".into()));
    }
    Ok(Negotiated { version, features: peer_features & bin::FEAT_ALL })
}

/// Run the dialing side of the handshake on a blocking stream. An EOF
/// here is the legacy-JSON-server signature (it read our magic as an
/// oversized frame and closed) — callers treat any error as "fall back
/// to the JSON channel".
pub fn client_handshake(stream: &mut TcpStream, timeout: Duration) -> Result<Negotiated, DqError> {
    stream.set_read_timeout(Some(timeout))?;
    stream.write_all(&hello())?;
    stream.flush()?;
    let mut reply = [0u8; 6];
    stream.read_exact(&mut reply).map_err(|e| {
        DqError::Io(format!("mux handshake got no hello (JSON-only peer?): {e}"))
    })?;
    if reply[..4] != MAGIC {
        return Err(DqError::Protocol("mux: bad handshake magic from peer".into()));
    }
    let negotiated = negotiate(reply[4], reply[5])?;
    stream.set_read_timeout(None)?;
    Ok(negotiated)
}

/// Read exactly one frame from a blocking stream (attach exchange only
/// — everything after it is nonblocking and loop-driven).
fn read_frame_blocking(stream: &mut TcpStream) -> Result<Frame, DqError> {
    let mut header = [0u8; 8];
    stream.read_exact(&mut header).map_err(|e| DqError::Io(format!("mux attach read: {e}")))?;
    let len = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
    if len > MAX_FRAME {
        return Err(DqError::Protocol(format!("mux: frame of {len} bytes exceeds cap")));
    }
    let crc = u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
    let mut body = vec![0u8; len as usize];
    stream.read_exact(&mut body).map_err(|e| DqError::Io(format!("mux attach read: {e}")))?;
    if crc32(&body) != crc {
        return Err(DqError::Protocol("mux: frame checksum mismatch".into()));
    }
    parse_body(&body)
}

/// Run the attach exchange on a fresh handshaken (still blocking)
/// stream: send `attach(token)` as correlation id 0, read the reply.
/// Returns `(token, resumed, last_req_corr)`.
fn client_attach(
    stream: &mut TcpStream,
    token: u64,
    timeout: Duration,
) -> Result<(u64, bool, u64), DqError> {
    stream.set_read_timeout(Some(timeout))?;
    let frame = encode_frame(KIND_REQ, 0, bin::OP_ATTACH, &bin::encode_attach_request(token));
    stream.write_all(&frame)?;
    stream.flush()?;
    let reply = read_frame_blocking(stream)?;
    let out = match reply.kind {
        KIND_OK if reply.corr == 0 => bin::decode_attach_ok(&reply.payload)?,
        KIND_ERR => return Err(bin::decode_error(&reply.payload).unwrap_or_else(|e| e)),
        k => {
            return Err(DqError::Protocol(format!(
                "mux: expected attach reply, got frame kind {k} corr {}",
                reply.corr
            )))
        }
    };
    stream.set_read_timeout(None)?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// poll-tolerant exact reads (shared with net/rpc's sniffing loop)
// ---------------------------------------------------------------------------

/// Outcome of [`poll_read_exact`].
pub(crate) enum PollRead {
    /// Buffer fully read.
    Done,
    /// Clean EOF before the first byte.
    Eof,
    /// The stop flag was raised while waiting.
    Stopped,
}

/// Read exactly `buf.len()` bytes, tolerating read-timeout polls
/// (`WouldBlock`/`TimedOut`) without losing partial data — unlike
/// `read_exact`, whose buffer state is unspecified on error. EOF after
/// partial data is an error (a torn frame), EOF at offset 0 is clean.
pub(crate) fn poll_read_exact(
    r: &mut impl Read,
    buf: &mut [u8],
    stop: &AtomicBool,
) -> std::io::Result<PollRead> {
    let mut filled = 0;
    while filled < buf.len() {
        if stop.load(Ordering::Relaxed) {
            return Ok(PollRead::Stopped);
        }
        match r.read(&mut buf[filled..]) {
            Ok(0) if filled == 0 => return Ok(PollRead::Eof),
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof inside a frame",
                ))
            }
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e),
        }
    }
    Ok(PollRead::Done)
}

// ---------------------------------------------------------------------------
// the multiplexer (dialing side: the co-Manager)
// ---------------------------------------------------------------------------

/// Tuning knobs for a [`Mux`].
#[derive(Debug, Clone)]
pub struct MuxConfig {
    /// Ping a connection with no inbound traffic for this long.
    pub ping_interval: Duration,
    /// Tear a connection down (failing its pending requests
    /// `WorkerLost`) after this long without any inbound traffic.
    pub idle_timeout: Duration,
    /// Per-connection cap on in-flight requests (backpressure).
    pub max_inflight: usize,
    /// Per-connection cap on queued unwritten bytes (backpressure).
    pub write_high_water: usize,
    /// Dial budget: TCP connect retries (capped backoff) + handshake.
    pub connect_timeout: Duration,
    /// How long a transport-faulted resumable connection may redial
    /// before its pending requests fail with the original error.
    /// `Duration::ZERO` disables in-place reconnect.
    pub revive_window: Duration,
    /// Cap on the torn-down-connection id set (oldest entries are
    /// pruned) so week-long processes under worker churn don't leak
    /// one entry per flap.
    pub max_dead: usize,
}

impl Default for MuxConfig {
    fn default() -> MuxConfig {
        MuxConfig {
            ping_interval: Duration::from_secs(10),
            idle_timeout: Duration::from_secs(60),
            max_inflight: 1024,
            write_high_water: 8 << 20,
            connect_timeout: Duration::from_secs(5),
            revive_window: Duration::from_secs(2),
            max_dead: 1024,
        }
    }
}

/// A connection handle returned by [`Mux::connect`].
#[derive(Debug, Clone, Copy)]
pub struct MuxConn {
    pub id: u64,
    pub negotiated: Negotiated,
}

type Callback = Box<dyn FnOnce(Result<Vec<u8>, DqError>) + Send + 'static>;

/// Push-event observer for a streaming request (shared, re-invocable).
pub type PushFn = Arc<dyn Fn(Vec<u8>) + Send + Sync + 'static>;

/// Completion-side callback of a pending request.
enum PendingCb {
    /// Plain request: one reply, then done.
    Oneshot(Callback),
    /// Streaming request: `push` per `KIND_PUSH`, `done` on OK/ERR.
    Stream { push: PushFn, done: Callback },
}

impl PendingCb {
    fn into_done(self) -> Callback {
        match self {
            PendingCb::Oneshot(cb) => cb,
            PendingCb::Stream { done, .. } => done,
        }
    }
}

/// One in-flight request. The encoded frame is retained on resumable
/// connections until the reply arrives, so an in-place reconnect can
/// re-send exactly the frames the server never received.
struct Pending {
    frame: Vec<u8>,
    cb: PendingCb,
}

/// A deferred unit of completion work (callbacks and push events run on
/// the `mux-done` thread, in the order the loop produced them — which
/// preserves per-stream push order).
type DoneTask = Box<dyn FnOnce() + Send + 'static>;

enum Cmd {
    Register {
        id: u64,
        stream: TcpStream,
        token: Option<u64>,
        addr: Option<SocketAddr>,
    },
    Request {
        conn: u64,
        op: u32,
        payload: Vec<u8>,
        cb: PendingCb,
    },
    /// A redialer brought a torn-down connection back.
    Revived {
        id: u64,
        stream: TcpStream,
        token: u64,
        resumed: bool,
        last_req_corr: u64,
    },
    /// A redialer exhausted its window.
    ReviveFailed {
        id: u64,
        err: DqError,
    },
}

/// The capped set of permanently torn-down connection ids. Bounded:
/// entries are pruned oldest-first past `cap`, and a successfully
/// revived connection never enters at all.
struct DeadSet {
    order: VecDeque<u64>,
    set: HashSet<u64>,
    cap: usize,
}

impl DeadSet {
    fn new(cap: usize) -> DeadSet {
        DeadSet { order: VecDeque::new(), set: HashSet::new(), cap: cap.max(1) }
    }

    fn insert(&mut self, id: u64) {
        if self.set.insert(id) {
            self.order.push_back(id);
            while self.order.len() > self.cap {
                if let Some(old) = self.order.pop_front() {
                    self.set.remove(&old);
                }
            }
        }
    }

    fn contains(&self, id: u64) -> bool {
        self.set.contains(&id)
    }

    fn len(&self) -> usize {
        self.set.len()
    }
}

struct Shared {
    cmds: Mutex<Vec<Cmd>>,
    cv: Condvar,
    stop: AtomicBool,
    /// Connections the loop has torn down for good: requests fail fast.
    dead: Mutex<DeadSet>,
}

impl Shared {
    fn push(&self, cmd: Cmd) {
        self.cmds.lock().expect("mux cmd queue poisoned").push(cmd);
        self.cv.notify_all();
    }
}

/// The multiplexer: two threads total (event loop + completion runner)
/// regardless of connection or in-flight-request count.
pub struct Mux {
    shared: Arc<Shared>,
    cfg: MuxConfig,
    next_conn: std::sync::atomic::AtomicU64,
    loop_thread: Mutex<Option<JoinHandle<()>>>,
    runner_thread: Mutex<Option<JoinHandle<()>>>,
}

impl Mux {
    /// Spawn the event-loop and completion-runner threads.
    pub fn new(cfg: MuxConfig) -> Arc<Mux> {
        let shared = Arc::new(Shared {
            cmds: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            dead: Mutex::new(DeadSet::new(cfg.max_dead)),
        });
        let (done_tx, done_rx) = mpsc::channel::<DoneTask>();
        let shared2 = shared.clone();
        let cfg2 = cfg.clone();
        let loop_thread = std::thread::Builder::new()
            .name("mux-loop".into())
            .spawn(move || run_event_loop(shared2, cfg2, done_tx))
            .expect("spawn mux-loop");
        let runner_thread = std::thread::Builder::new()
            .name("mux-done".into())
            .spawn(move || {
                let _gauge = TransportGuard::enter();
                while let Ok(task) = done_rx.recv() {
                    task();
                }
            })
            .expect("spawn mux-done");
        Arc::new(Mux {
            shared,
            cfg,
            next_conn: std::sync::atomic::AtomicU64::new(1),
            loop_thread: Mutex::new(Some(loop_thread)),
            runner_thread: Mutex::new(Some(runner_thread)),
        })
    }

    /// Dial a peer (TCP connect under capped backoff + jitter, then the
    /// version handshake and — when `FEAT_RESUME` is negotiated — the
    /// attach exchange) and hand the socket to the event loop. Errors
    /// mean "this peer does not speak mux" — callers fall back to JSON.
    pub fn connect<A: ToSocketAddrs + Clone>(&self, addr: A) -> Result<MuxConn, DqError> {
        if self.shared.stop.load(Ordering::Relaxed) {
            return Err(DqError::Cancelled("mux is shut down".into()));
        }
        let mut stream = backoff::retry(
            self.cfg.connect_timeout,
            Duration::from_millis(10),
            Duration::from_millis(500),
            || TcpStream::connect(addr.clone()),
        )
        .map_err(|e| DqError::Io(format!("mux connect failed: {e}")))?;
        stream.set_nodelay(true).map_err(|e| DqError::Io(e.to_string()))?;
        let negotiated = client_handshake(&mut stream, self.cfg.connect_timeout)?;
        let (token, peer) = if negotiated.features & bin::FEAT_RESUME != 0 {
            let peer = stream.peer_addr().map_err(|e| DqError::Io(e.to_string()))?;
            let (token, _resumed, _last) =
                client_attach(&mut stream, 0, self.cfg.connect_timeout)?;
            (Some(token), Some(peer))
        } else {
            (None, None)
        };
        stream.set_nonblocking(true).map_err(|e| DqError::Io(e.to_string()))?;
        let id = self.next_conn.fetch_add(1, Ordering::Relaxed);
        self.shared.push(Cmd::Register { id, stream, token, addr: peer });
        Ok(MuxConn { id, negotiated })
    }

    /// Enqueue-and-notify: hand a request to the event loop; `done`
    /// runs on the completion-runner thread (or inline, if the plane is
    /// already stopped). Never blocks on the network.
    pub fn request(&self, conn: u64, op: u32, payload: Vec<u8>, done: Callback) {
        self.submit(conn, op, payload, PendingCb::Oneshot(done));
    }

    /// Streaming request: `on_push` runs (on the completion runner, in
    /// arrival order) for every `KIND_PUSH` frame the server emits on
    /// this correlation id; `done` runs once on the final OK/ERR.
    pub fn request_stream(
        &self,
        conn: u64,
        op: u32,
        payload: Vec<u8>,
        on_push: PushFn,
        done: Callback,
    ) {
        self.submit(conn, op, payload, PendingCb::Stream { push: on_push, done });
    }

    fn submit(&self, conn: u64, op: u32, payload: Vec<u8>, cb: PendingCb) {
        if self.shared.stop.load(Ordering::Relaxed) {
            cb.into_done()(Err(DqError::Cancelled("mux is shut down".into())));
            return;
        }
        if self.is_dead(conn) {
            cb.into_done()(Err(DqError::WorkerLost(format!("mux connection {conn} is closed"))));
            return;
        }
        self.shared.push(Cmd::Request { conn, op, payload, cb });
    }

    /// Blocking convenience over [`Mux::request`].
    pub fn call(&self, conn: u64, op: u32, payload: Vec<u8>) -> Result<Vec<u8>, DqError> {
        let (tx, rx) = mpsc::channel();
        self.request(
            conn,
            op,
            payload,
            Box::new(move |res| {
                let _ = tx.send(res);
            }),
        );
        rx.recv().unwrap_or_else(|_| Err(DqError::Cancelled("mux is shut down".into())))
    }

    /// Has the event loop torn this connection down for good? (False
    /// while an in-place revival is still in flight — requests queue.)
    pub fn is_dead(&self, conn: u64) -> bool {
        self.shared.dead.lock().expect("mux dead set poisoned").contains(conn)
    }

    /// Size of the torn-down-connection set (bounded by
    /// [`MuxConfig::max_dead`]; regression-tested under churn).
    pub fn dead_len(&self) -> usize {
        self.shared.dead.lock().expect("mux dead set poisoned").len()
    }

    /// Stop both threads, failing every pending request `Cancelled`.
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(t) = self.loop_thread.lock().expect("mux join poisoned").take() {
            let _ = t.join();
        }
        if let Some(t) = self.runner_thread.lock().expect("mux join poisoned").take() {
            let _ = t.join();
        }
    }
}

impl Drop for Mux {
    fn drop(&mut self) {
        self.shutdown();
    }
}

struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    woff: usize,
    pending: HashMap<u64, Pending>,
    next_corr: u64,
    last_rx: Instant,
    last_ping: Instant,
    /// Session token (resumable connections only).
    token: Option<u64>,
    /// Peer address, for in-place redial.
    addr: Option<SocketAddr>,
}

impl Conn {
    fn queued_bytes(&self) -> usize {
        self.wbuf.len() - self.woff
    }

    fn resumable(&self) -> bool {
        self.token.is_some() && self.addr.is_some()
    }
}

/// A torn-down connection whose socket is being redialed in place. New
/// requests keep accumulating here (they are re-sent on revival, being
/// above the server's watermark by construction).
struct Reviving {
    pending: HashMap<u64, Pending>,
    next_corr: u64,
    addr: SocketAddr,
}

/// Dial + handshake + re-attach, once. Any error is retried by the
/// redialer under backoff until its window closes.
fn try_revive(
    addr: SocketAddr,
    token: u64,
    timeout: Duration,
) -> Result<(TcpStream, u64, bool, u64), DqError> {
    let mut stream =
        TcpStream::connect_timeout(&addr, timeout).map_err(|e| DqError::Io(e.to_string()))?;
    stream.set_nodelay(true).map_err(|e| DqError::Io(e.to_string()))?;
    let negotiated = client_handshake(&mut stream, timeout)?;
    if negotiated.features & bin::FEAT_RESUME == 0 {
        return Err(DqError::Protocol("mux: peer no longer supports session resume".into()));
    }
    let (tok, resumed, last) = client_attach(&mut stream, token, timeout)?;
    stream.set_nonblocking(true).map_err(|e| DqError::Io(e.to_string()))?;
    Ok((stream, tok, resumed, last))
}

/// Transient (non-transport-gauged) redial thread for one torn-down
/// connection: capped-backoff dial attempts until the revive window
/// closes, then report either way through the command queue.
fn spawn_redialer(shared: Arc<Shared>, cfg: &MuxConfig, id: u64, addr: SocketAddr, token: u64, cause: DqError) {
    let window = cfg.revive_window;
    let attempt_timeout = cfg.connect_timeout.min(Duration::from_millis(500)).max(Duration::from_millis(50));
    let _ = std::thread::Builder::new().name(format!("mux-redial-{id}")).spawn(move || {
        let deadline = Instant::now() + window;
        let mut backoff = backoff::Backoff::new(
            Duration::from_millis(25),
            Duration::from_millis(250),
            backoff::auto_seed(),
        );
        loop {
            if shared.stop.load(Ordering::Relaxed) {
                return; // the loop drains `reviving` on shutdown
            }
            match try_revive(addr, token, attempt_timeout) {
                Ok((stream, tok, resumed, last_req_corr)) => {
                    shared.push(Cmd::Revived { id, stream, token: tok, resumed, last_req_corr });
                    return;
                }
                Err(_) if Instant::now() < deadline => {
                    let nap = backoff
                        .next_delay()
                        .min(deadline.saturating_duration_since(Instant::now()));
                    std::thread::sleep(nap);
                }
                Err(e) => {
                    crate::log_warn!(
                        "mux",
                        "connection {id} revival gave up after {window:?}: {e} (drop cause: {cause})"
                    );
                    shared.push(Cmd::ReviveFailed { id, err: cause });
                    return;
                }
            }
        }
    });
}

fn run_event_loop(shared: Arc<Shared>, cfg: MuxConfig, done: mpsc::Sender<DoneTask>) {
    let _gauge = TransportGuard::enter();
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut reviving: HashMap<u64, Reviving> = HashMap::new();
    let mut scratch = vec![0u8; 64 * 1024];
    let mut progress = true;
    let complete = |cb: PendingCb, res: Result<Vec<u8>, DqError>| {
        let cb = cb.into_done();
        let _ = done.send(Box::new(move || cb(res)));
    };
    loop {
        // Drain commands; park 1 ms only when the last scan was idle.
        let cmds: Vec<Cmd> = {
            let mut q = shared.cmds.lock().expect("mux cmd queue poisoned");
            if q.is_empty() && !progress && !shared.stop.load(Ordering::Relaxed) {
                q = shared.cv.wait_timeout(q, Duration::from_millis(1)).expect("mux cv").0;
            }
            std::mem::take(&mut *q)
        };
        if shared.stop.load(Ordering::Relaxed) {
            for (_, conn) in conns.drain() {
                for (_, p) in conn.pending {
                    complete(p.cb, Err(DqError::Cancelled("mux is shut down".into())));
                }
            }
            for (_, r) in reviving.drain() {
                for (_, p) in r.pending {
                    complete(p.cb, Err(DqError::Cancelled("mux is shut down".into())));
                }
            }
            for cmd in cmds {
                if let Cmd::Request { cb, .. } = cmd {
                    complete(cb, Err(DqError::Cancelled("mux is shut down".into())));
                }
            }
            return;
        }
        progress = false;
        let now = Instant::now();
        for cmd in cmds {
            progress = true;
            match cmd {
                Cmd::Register { id, stream, token, addr } => {
                    conns.insert(
                        id,
                        Conn {
                            stream,
                            rbuf: Vec::new(),
                            wbuf: Vec::new(),
                            woff: 0,
                            pending: HashMap::new(),
                            next_corr: 1,
                            last_rx: now,
                            last_ping: now,
                            token,
                            addr,
                        },
                    );
                }
                Cmd::Request { conn, op, payload, cb } => match conns.get_mut(&conn) {
                    None => match reviving.get_mut(&conn) {
                        // mid-revival: park the request; it re-sends on
                        // the fresh socket (its corr is above the
                        // watermark by construction)
                        Some(r) if r.pending.len() >= cfg.max_inflight => complete(
                            cb,
                            Err(DqError::Io(format!(
                                "mux backpressure: {} requests in flight on connection {conn}",
                                r.pending.len()
                            ))),
                        ),
                        Some(r) => {
                            let corr = r.next_corr;
                            r.next_corr += 1;
                            let frame = encode_frame(KIND_REQ, corr, op, &payload);
                            r.pending.insert(corr, Pending { frame, cb });
                        }
                        None => complete(
                            cb,
                            Err(DqError::WorkerLost(format!("mux connection {conn} is closed"))),
                        ),
                    },
                    Some(c) if c.pending.len() >= cfg.max_inflight => complete(
                        cb,
                        Err(DqError::Io(format!(
                            "mux backpressure: {} requests in flight on connection {conn}",
                            c.pending.len()
                        ))),
                    ),
                    Some(c) if c.queued_bytes() > cfg.write_high_water => complete(
                        cb,
                        Err(DqError::Io(format!(
                            "mux backpressure: {} bytes queued on connection {conn}",
                            c.queued_bytes()
                        ))),
                    ),
                    Some(c) => {
                        let corr = c.next_corr;
                        c.next_corr += 1;
                        let frame = encode_frame(KIND_REQ, corr, op, &payload);
                        c.wbuf.extend_from_slice(&frame);
                        // retain the frame only where a revival could
                        // ever re-send it
                        let retained = if c.resumable() { frame } else { Vec::new() };
                        c.pending.insert(corr, Pending { frame: retained, cb });
                    }
                },
                Cmd::Revived { id, stream, token, resumed, last_req_corr } => {
                    let Some(mut r) = reviving.remove(&id) else {
                        continue; // already failed/stopped; drop the socket
                    };
                    let mut wbuf = Vec::new();
                    let mut pending = std::mem::take(&mut r.pending);
                    if resumed {
                        // Re-send exactly the frames the server never
                        // received, in correlation order; everything at
                        // or below the watermark has a parked reply
                        // coming.
                        let mut corrs: Vec<u64> =
                            pending.keys().copied().filter(|c| *c > last_req_corr).collect();
                        corrs.sort_unstable();
                        for corr in &corrs {
                            wbuf.extend_from_slice(&pending[corr].frame);
                        }
                        crate::log_warn!(
                            "mux",
                            "connection {id} revived in place (resumed session, {} of {} pending re-sent)",
                            corrs.len(),
                            pending.len()
                        );
                    } else {
                        // The server lost the session (restart, linger
                        // expiry): in-flight effects are unknowable, so
                        // fail them — but the connection itself
                        // continues fresh under the same id.
                        crate::log_warn!(
                            "mux",
                            "connection {id} reconnected but the session expired; failing {} pending",
                            pending.len()
                        );
                        for (_, p) in pending.drain() {
                            complete(
                                p.cb,
                                Err(DqError::WorkerLost(
                                    "mux session expired across reconnect".into(),
                                )),
                            );
                        }
                    }
                    conns.insert(
                        id,
                        Conn {
                            stream,
                            rbuf: Vec::new(),
                            wbuf,
                            woff: 0,
                            pending,
                            next_corr: r.next_corr,
                            last_rx: now,
                            last_ping: now,
                            token: Some(token),
                            addr: Some(r.addr),
                        },
                    );
                }
                Cmd::ReviveFailed { id, err } => {
                    if let Some(r) = reviving.remove(&id) {
                        shared.dead.lock().expect("mux dead set poisoned").insert(id);
                        for (_, p) in r.pending {
                            complete(p.cb, Err(err.clone()));
                        }
                    }
                }
            }
        }
        // (id, error, transport_fault): transport faults on resumable
        // connections are revived in place; everything else is fatal.
        let mut doomed: Vec<(u64, DqError, bool)> = Vec::new();
        for (&id, conn) in conns.iter_mut() {
            // 1. flush the write queue as far as the socket accepts
            while conn.woff < conn.wbuf.len() {
                match conn.stream.write(&conn.wbuf[conn.woff..]) {
                    Ok(0) => {
                        doomed.push((id, DqError::WorkerLost("mux write end closed".into()), true));
                        break;
                    }
                    Ok(n) => {
                        conn.woff += n;
                        progress = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        doomed.push((
                            id,
                            DqError::WorkerLost(format!("mux write failed: {e}")),
                            true,
                        ));
                        break;
                    }
                }
            }
            if conn.woff == conn.wbuf.len() {
                conn.wbuf.clear();
                conn.woff = 0;
            } else if conn.woff > 64 * 1024 {
                conn.wbuf.drain(..conn.woff);
                conn.woff = 0;
            }
            if doomed.last().is_some_and(|(d, _, _)| *d == id) {
                continue;
            }
            // 2. read whatever is available
            loop {
                match conn.stream.read(&mut scratch) {
                    Ok(0) => {
                        doomed.push((id, DqError::WorkerLost("mux peer closed".into()), true));
                        break;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&scratch[..n]);
                        conn.last_rx = now;
                        progress = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        doomed.push((
                            id,
                            DqError::WorkerLost(format!("mux read failed: {e}")),
                            true,
                        ));
                        break;
                    }
                }
            }
            if doomed.last().is_some_and(|(d, _, _)| *d == id) {
                continue;
            }
            // 3. complete whole frames
            loop {
                match take_frame(&mut conn.rbuf) {
                    Ok(None) => break,
                    Ok(Some(f)) => match f.kind {
                        KIND_OK => {
                            if let Some(p) = conn.pending.remove(&f.corr) {
                                complete(p.cb, Ok(f.payload));
                            }
                        }
                        KIND_ERR => {
                            if let Some(p) = conn.pending.remove(&f.corr) {
                                let e = bin::decode_error(&f.payload).unwrap_or_else(|e| e);
                                complete(p.cb, Err(e));
                            }
                        }
                        KIND_PUSH => {
                            // unsolicited event on an open stream; the
                            // done channel serializes pushes with
                            // completions, preserving arrival order
                            if let Some(p) = conn.pending.get(&f.corr) {
                                if let PendingCb::Stream { push, .. } = &p.cb {
                                    let push = push.clone();
                                    let payload = f.payload;
                                    let _ = done.send(Box::new(move || push(payload)));
                                }
                            }
                        }
                        KIND_PONG => {}
                        _ => {
                            doomed.push((
                                id,
                                DqError::Protocol(format!(
                                    "mux: unexpected frame kind {} from responder",
                                    f.kind
                                )),
                                false,
                            ));
                            break;
                        }
                    },
                    Err(e) => {
                        doomed.push((id, e, false));
                        break;
                    }
                }
            }
            if doomed.last().is_some_and(|(d, _, _)| *d == id) {
                continue;
            }
            // 4. liveness: ping quiet peers, doom silent ones
            let quiet = now.saturating_duration_since(conn.last_rx);
            if quiet > cfg.idle_timeout {
                doomed.push((
                    id,
                    DqError::WorkerLost(format!(
                        "mux idle timeout: no traffic for {:.1}s",
                        quiet.as_secs_f64()
                    )),
                    // the peer is reachable-but-silent: redialing it
                    // would just recreate the hang, so stay fatal
                    false,
                ));
            } else if quiet >= cfg.ping_interval
                && now.saturating_duration_since(conn.last_ping) >= cfg.ping_interval
            {
                conn.wbuf.extend_from_slice(&encode_frame(KIND_PING, 0, 0, &[]));
                conn.last_ping = now;
            }
        }
        for (id, err, transport_fault) in doomed {
            if let Some(conn) = conns.remove(&id) {
                let revivable = transport_fault
                    && conn.resumable()
                    && cfg.revive_window > Duration::ZERO
                    && !shared.stop.load(Ordering::Relaxed);
                if revivable {
                    let token = conn.token.unwrap();
                    let addr = conn.addr.unwrap();
                    crate::log_warn!(
                        "mux",
                        "connection {id} dropped ({err}); redialing in place ({} pending retained)",
                        conn.pending.len()
                    );
                    reviving.insert(
                        id,
                        Reviving { pending: conn.pending, next_corr: conn.next_corr, addr },
                    );
                    spawn_redialer(shared.clone(), &cfg, id, addr, token, err);
                } else {
                    crate::log_warn!("mux", "connection {id} torn down: {err}");
                    shared.dead.lock().expect("mux dead set poisoned").insert(id);
                    for (_, p) in conn.pending {
                        complete(p.cb, Err(err.clone()));
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// the single-threaded server park (answering side at scale)
// ---------------------------------------------------------------------------

/// How long a detached session (its connection dropped, nobody
/// re-attached yet) is retained before being reaped.
const SESSION_LINGER: Duration = Duration::from_secs(30);

/// Cap on a detached session's parked bytes; past it the session is
/// dropped (the client's re-attach starts fresh) rather than growing
/// unboundedly while nobody drains it.
const SESSION_BUF_CAP: usize = 32 << 20;

/// A binary-only server that serves *all* accepted (or adopted)
/// connections from one readiness-scan thread — the answering-side twin
/// of [`Mux`]. The 256-client scale bench parks every connection here,
/// which is what keeps the whole transport at 3 threads. Fast handlers
/// run inline on the loop thread; blocking ops ([`MuxService::defer`])
/// run on transient threads and reply through the session out-queue;
/// streaming ops ([`MuxService::open_stream`]) push unsolicited frames
/// the same way.
pub struct MuxServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    adopt: Arc<Mutex<Vec<(TcpStream, Vec<u8>)>>>,
    thread: Option<JoinHandle<()>>,
}

impl MuxServer {
    /// Bind (port 0 for ephemeral) and start the serve loop.
    pub fn serve<A: ToSocketAddrs>(
        addr: A,
        service: Arc<dyn MuxService>,
    ) -> std::io::Result<MuxServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        Ok(Self::start(Some(listener), local, service))
    }

    /// A listener-less park: connections arrive only through
    /// [`MuxServer::adopt`] (the dual-codec `RpcServer` sniffs the
    /// magic on its own listener, then hands the socket over). This is
    /// how *every* `serve_bin` endpoint — manager and worker alike —
    /// now serves its binary clients from one transport thread.
    pub fn adoptive(service: Arc<dyn MuxService>) -> MuxServer {
        let placeholder: SocketAddr = ([0, 0, 0, 0], 0).into();
        Self::start(None, placeholder, service)
    }

    fn start(
        listener: Option<TcpListener>,
        local: SocketAddr,
        service: Arc<dyn MuxService>,
    ) -> MuxServer {
        let stop = Arc::new(AtomicBool::new(false));
        let adopt: Arc<Mutex<Vec<(TcpStream, Vec<u8>)>>> = Arc::new(Mutex::new(Vec::new()));
        let stop2 = stop.clone();
        let adopt2 = adopt.clone();
        let thread = std::thread::Builder::new()
            .name("mux-server".into())
            .spawn(move || run_server_loop(listener, service, stop2, adopt2))
            .expect("spawn mux-server");
        MuxServer { addr: local, stop, adopt, thread: Some(thread) }
    }

    /// Hand an accepted socket to the park. `consumed` is whatever the
    /// caller already read while sniffing the codec (the 4 magic
    /// bytes); it seeds the connection's receive buffer so the in-band
    /// hello parses exactly as if the park had read it itself.
    pub fn adopt(&self, stream: TcpStream, consumed: &[u8]) {
        let _ = stream.set_nonblocking(true);
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(None);
        self.adopt
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push((stream, consumed.to_vec()));
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop and join the serve loop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MuxServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Server-side resumable session state, owned by the park loop.
struct Session {
    out: Arc<OutQueue>,
    /// Highest request correlation id ever received (the watermark the
    /// attach reply reports; requests at or below it are duplicates).
    last_req_corr: u64,
    /// Bumped on every attach; only the connection holding the current
    /// epoch may drain `out` (a half-open predecessor is killed).
    epoch: u64,
    attached: bool,
    detached_at: Option<Instant>,
}

struct ServerConn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    woff: usize,
    greeted: bool,
    alive: bool,
    /// Reply/push queue; replaced by the session's queue on attach.
    out: Arc<OutQueue>,
    /// Attached session token + the epoch this connection holds it at.
    token: Option<u64>,
    epoch: u64,
    /// Superseded by a newer attach: die without detaching the session.
    stale: bool,
}

impl ServerConn {
    fn new(stream: TcpStream, seed: Vec<u8>) -> ServerConn {
        ServerConn {
            stream,
            rbuf: seed,
            wbuf: Vec::new(),
            woff: 0,
            greeted: false,
            alive: true,
            out: OutQueue::new(),
            token: None,
            epoch: 0,
            stale: false,
        }
    }
}

fn run_server_loop(
    listener: Option<TcpListener>,
    service: Arc<dyn MuxService>,
    stop: Arc<AtomicBool>,
    adopt: Arc<Mutex<Vec<(TcpStream, Vec<u8>)>>>,
) {
    let _gauge = TransportGuard::enter();
    let mut conns: Vec<ServerConn> = Vec::new();
    let mut sessions: HashMap<u64, Session> = HashMap::new();
    let mut next_token: u64 = 1;
    let mut scratch = vec![0u8; 64 * 1024];
    let mut accepting = listener.is_some();
    while !stop.load(Ordering::Relaxed) {
        let mut progress = false;
        if let Some(listener) = &listener {
            while accepting {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let _ = stream.set_nonblocking(true);
                        let _ = stream.set_nodelay(true);
                        conns.push(ServerConn::new(stream, Vec::new()));
                        progress = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        // Fatal listener error: stop accepting, keep serving
                        // the connections that already exist.
                        crate::log_warn!("mux", "mux-server accept failed fatally: {e}");
                        accepting = false;
                    }
                }
            }
        }
        {
            let mut q = adopt.lock().unwrap_or_else(|e| e.into_inner());
            for (stream, seed) in q.drain(..) {
                conns.push(ServerConn::new(stream, seed));
                progress = true;
            }
        }
        for conn in conns.iter_mut() {
            // a newer attach stole this connection's session: kill the
            // half-open predecessor without touching the session
            if let Some(tok) = conn.token {
                let current = sessions.get(&tok).map(|s| s.epoch);
                if current != Some(conn.epoch) {
                    conn.alive = false;
                    conn.stale = true;
                }
            }
            if conn.alive {
                progress |=
                    serve_park_conn(conn, &service, &mut scratch, &mut sessions, &mut next_token);
            }
        }
        for conn in conns.iter() {
            if !conn.alive && !conn.stale {
                if let Some(tok) = conn.token {
                    if let Some(s) = sessions.get_mut(&tok) {
                        if s.epoch == conn.epoch && s.attached {
                            s.attached = false;
                            s.detached_at = Some(Instant::now());
                        }
                    }
                }
            }
        }
        conns.retain(|c| c.alive);
        sessions.retain(|_, s| {
            s.attached
                || (s.detached_at.is_some_and(|t| t.elapsed() < SESSION_LINGER)
                    && s.out.len() <= SESSION_BUF_CAP)
        });
        if !progress {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
}

/// Handle one attach request on a park connection.
fn park_attach(
    conn: &mut ServerConn,
    payload: &[u8],
    sessions: &mut HashMap<u64, Session>,
    next_token: &mut u64,
) -> Result<Vec<u8>, DqError> {
    if conn.token.is_some() {
        return Err(DqError::Protocol("mux: connection is already attached".into()));
    }
    let want = bin::decode_attach_request(payload)?;
    if want != 0 {
        if let Some(s) = sessions.get_mut(&want) {
            s.epoch += 1;
            s.attached = true;
            s.detached_at = None;
            conn.token = Some(want);
            conn.epoch = s.epoch;
            conn.out = s.out.clone();
            return Ok(bin::encode_attach_ok(want, true, s.last_req_corr));
        }
        // unknown/expired token: fall through to a fresh session — the
        // dialer fails its old pendings and carries on
    }
    let token = *next_token;
    *next_token += 1;
    sessions.insert(
        token,
        Session {
            out: conn.out.clone(),
            last_req_corr: 0,
            epoch: 1,
            attached: true,
            detached_at: None,
        },
    );
    conn.token = Some(token);
    conn.epoch = 1;
    Ok(bin::encode_attach_ok(token, false, 0))
}

/// One readiness pass over one server-side connection; returns whether
/// any bytes moved.
fn serve_park_conn(
    conn: &mut ServerConn,
    service: &Arc<dyn MuxService>,
    scratch: &mut [u8],
    sessions: &mut HashMap<u64, Session>,
    next_token: &mut u64,
) -> bool {
    let mut progress = false;
    // stage queued replies/pushes (session or connection queue)
    progress |= conn.out.drain_into(&mut conn.wbuf);
    // flush pending responses
    while conn.woff < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.woff..]) {
            Ok(0) => {
                conn.alive = false;
                return progress;
            }
            Ok(n) => {
                conn.woff += n;
                progress = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                conn.alive = false;
                return progress;
            }
        }
    }
    if conn.woff == conn.wbuf.len() {
        conn.wbuf.clear();
        conn.woff = 0;
    }
    // read available bytes
    loop {
        match conn.stream.read(scratch) {
            Ok(0) => {
                conn.alive = false;
                return progress;
            }
            Ok(n) => {
                conn.rbuf.extend_from_slice(&scratch[..n]);
                progress = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                conn.alive = false;
                return progress;
            }
        }
    }
    // handshake, then serve complete frames
    if !conn.greeted {
        if conn.rbuf.len() < 6 {
            return progress;
        }
        if conn.rbuf[..4] != MAGIC || negotiate(conn.rbuf[4], conn.rbuf[5]).is_err() {
            conn.alive = false;
            return progress;
        }
        conn.rbuf.drain(..6);
        conn.wbuf.extend_from_slice(&hello());
        conn.greeted = true;
        progress = true;
    }
    loop {
        match take_frame(&mut conn.rbuf) {
            Ok(None) => break,
            Ok(Some(f)) => {
                progress = true;
                match f.kind {
                    KIND_PING => {
                        conn.wbuf.extend_from_slice(&encode_frame(KIND_PONG, f.corr, 0, &[]));
                    }
                    KIND_REQ if f.op == bin::OP_ATTACH => {
                        // the attach reply goes straight to the write
                        // buffer so it precedes any parked bytes the
                        // resumed session drains on the next pass
                        let reply = park_attach(conn, &f.payload, sessions, next_token);
                        conn.wbuf.extend_from_slice(&reply_frame(f.corr, reply));
                    }
                    KIND_REQ => {
                        // session watermark: skip requests the session
                        // already received (a re-sent duplicate after
                        // reconnect) — exactly-once dispatch
                        if let Some(tok) = conn.token {
                            if let Some(s) = sessions.get_mut(&tok) {
                                if f.corr <= s.last_req_corr {
                                    continue;
                                }
                                s.last_req_corr = f.corr;
                            }
                        }
                        dispatch_park_req(conn, service, f);
                    }
                    _ => {
                        conn.alive = false;
                        return progress;
                    }
                }
            }
            Err(_) => {
                conn.alive = false;
                return progress;
            }
        }
    }
    // anything a handler queued this pass goes out without waiting for
    // the next loop iteration
    progress |= conn.out.drain_into(&mut conn.wbuf);
    progress
}

/// Route one non-attach request: streaming ops keep their correlation
/// id open, deferred ops run on a transient thread, everything else
/// dispatches inline. All replies ride the out-queue so they interleave
/// with pushes in production order (and park across a reconnect).
fn dispatch_park_req(conn: &mut ServerConn, service: &Arc<dyn MuxService>, f: Frame) {
    let pusher = Pusher { out: conn.out.clone(), corr: f.corr };
    match service.open_stream(f.op, &f.payload, pusher) {
        Some(Ok(())) => {} // stream open; the service finishes it later
        Some(Err(e)) => {
            conn.out.append(&reply_frame(f.corr, Err(e)));
        }
        None if service.defer(f.op) => {
            let svc = service.clone();
            let out = conn.out.clone();
            let (op, corr, payload) = (f.op, f.corr, f.payload);
            let spawned = std::thread::Builder::new().name("mux-defer".into()).spawn(move || {
                let res = svc.handle(op, &payload);
                out.append(&reply_frame(corr, res));
            });
            if spawned.is_err() {
                conn.out.append(&reply_frame(
                    f.corr,
                    Err(DqError::Io("mux: failed to spawn deferred handler".into())),
                ));
            }
        }
        None => {
            let res = service.handle(f.op, &f.payload);
            conn.out.append(&reply_frame(f.corr, res));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_service() -> Arc<dyn MuxService> {
        Arc::new(|op: u32, payload: &[u8]| -> Result<Vec<u8>, DqError> {
            match op {
                7 => Ok(payload.to_vec()),
                8 => Err(DqError::Cancelled("op 8 always cancels".into())),
                _ => Err(DqError::Protocol(format!("unknown op {op}"))),
            }
        })
    }

    #[test]
    fn frame_round_trips() {
        let mut buf = encode_frame(KIND_REQ, 42, 7, b"hello");
        let f = take_frame(&mut buf).unwrap().unwrap();
        assert_eq!(f, Frame { kind: KIND_REQ, corr: 42, op: 7, payload: b"hello".to_vec() });
        assert!(buf.is_empty());
    }

    #[test]
    fn push_frames_parse() {
        let mut buf = encode_frame(KIND_PUSH, 9, 0, b"event");
        let f = take_frame(&mut buf).unwrap().unwrap();
        assert_eq!(f, Frame { kind: KIND_PUSH, corr: 9, op: 0, payload: b"event".to_vec() });
        // kinds past PUSH stay connection-fatal
        let mut bad = encode_frame(KIND_PUSH + 1, 1, 0, &[]);
        assert!(take_frame(&mut bad).is_err());
    }

    #[test]
    fn partial_frame_wants_more_bytes() {
        let full = encode_frame(KIND_OK, 1, 0, &[9u8; 100]);
        for cut in 0..full.len() {
            let mut partial = full[..cut].to_vec();
            assert!(take_frame(&mut partial).unwrap().is_none(), "cut at {cut}");
        }
    }

    #[test]
    fn bit_flips_are_rejected() {
        let full = encode_frame(KIND_OK, 3, 0, b"payload bytes");
        // flip every bit of the checksummed region (crc + body)
        for byte in 4..full.len() {
            for bit in 0..8 {
                let mut corrupt = full.clone();
                corrupt[byte] ^= 1 << bit;
                assert!(
                    take_frame(&mut corrupt).is_err(),
                    "flip at byte {byte} bit {bit} went undetected"
                );
            }
        }
    }

    #[test]
    fn call_round_trips_over_server_park() {
        let server = MuxServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let mux = Mux::new(MuxConfig::default());
        let conn = mux.connect(server.local_addr()).unwrap();
        assert_eq!(conn.negotiated.version, bin::BIN_VERSION);
        assert_eq!(conn.negotiated.features, bin::FEAT_ALL);
        let out = mux.call(conn.id, 7, b"ping pong".to_vec()).unwrap();
        assert_eq!(out, b"ping pong");
        assert!(matches!(mux.call(conn.id, 8, vec![]), Err(DqError::Cancelled(_))));
    }

    #[test]
    fn shutdown_cancels_pending_and_rejects_new() {
        let mux = Mux::new(MuxConfig::default());
        mux.shutdown();
        assert!(matches!(mux.call(1, 7, vec![]), Err(DqError::Cancelled(_))));
    }

    /// A service where op 21 opens a stream that pushes the payload
    /// twice and finishes OK, and op 22 is deferred.
    struct StreamingEcho;

    impl MuxService for StreamingEcho {
        fn handle(&self, op: u32, payload: &[u8]) -> Result<Vec<u8>, DqError> {
            match op {
                7 | 22 => Ok(payload.to_vec()),
                _ => Err(DqError::Protocol(format!("unknown op {op}"))),
            }
        }

        fn defer(&self, op: u32) -> bool {
            op == 22
        }

        fn open_stream(
            &self,
            op: u32,
            payload: &[u8],
            pusher: Pusher,
        ) -> Option<Result<(), DqError>> {
            if op != 21 {
                return None;
            }
            if payload.is_empty() {
                return Some(Err(DqError::Protocol("empty stream payload".into())));
            }
            pusher.push(payload);
            pusher.push(payload);
            pusher.finish(Ok(b"fin".to_vec()));
            Some(Ok(()))
        }
    }

    #[test]
    fn streams_push_in_order_then_finish() {
        let server = MuxServer::serve("127.0.0.1:0", Arc::new(StreamingEcho)).unwrap();
        let mux = Mux::new(MuxConfig::default());
        let conn = mux.connect(server.local_addr()).unwrap();

        let events = Arc::new(Mutex::new(Vec::<Vec<u8>>::new()));
        let (tx, rx) = mpsc::channel();
        let ev2 = events.clone();
        mux.request_stream(
            conn.id,
            21,
            b"ev".to_vec(),
            Arc::new(move |p| ev2.lock().unwrap().push(p)),
            Box::new(move |res| {
                let _ = tx.send(res);
            }),
        );
        let fin = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        assert_eq!(fin, b"fin");
        assert_eq!(*events.lock().unwrap(), vec![b"ev".to_vec(), b"ev".to_vec()]);

        // a rejected stream comes back as a typed error
        let (tx, rx) = mpsc::channel();
        mux.request_stream(
            conn.id,
            21,
            Vec::new(),
            Arc::new(|_| {}),
            Box::new(move |res| {
                let _ = tx.send(res);
            }),
        );
        let err = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap_err();
        assert!(matches!(err, DqError::Protocol(_)), "{err}");

        // deferred ops still answer on the same connection
        assert_eq!(mux.call(conn.id, 22, b"slowpoke".to_vec()).unwrap(), b"slowpoke");
        // and plain inline ops interleave fine
        assert_eq!(mux.call(conn.id, 7, b"quick".to_vec()).unwrap(), b"quick");
    }

    #[test]
    fn dead_set_is_bounded_under_connection_churn() {
        let mux = Mux::new(MuxConfig {
            // no revival: every teardown goes straight to the dead set
            revive_window: Duration::ZERO,
            max_dead: 4,
            ..MuxConfig::default()
        });
        let mut ids = Vec::new();
        for _ in 0..10 {
            let server = MuxServer::serve("127.0.0.1:0", echo_service()).unwrap();
            let conn = mux.connect(server.local_addr()).unwrap();
            ids.push(conn.id);
            drop(server); // peer closes; the loop reads EOF and tears down
            let deadline = Instant::now() + Duration::from_secs(10);
            while !mux.is_dead(conn.id) && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(2));
            }
            assert!(mux.is_dead(conn.id), "teardown not observed");
        }
        assert!(
            mux.dead_len() <= 4,
            "dead set must stay bounded under churn, got {}",
            mux.dead_len()
        );
        // the newest corpses are still queryable; the oldest were pruned
        assert!(mux.is_dead(*ids.last().unwrap()));
        assert!(!mux.is_dead(ids[0]));
        mux.shutdown();
    }

    #[test]
    fn dead_set_prunes_oldest_first() {
        let mut d = DeadSet::new(3);
        for id in 1..=5 {
            d.insert(id);
        }
        assert_eq!(d.len(), 3);
        assert!(!d.contains(1) && !d.contains(2));
        assert!(d.contains(3) && d.contains(4) && d.contains(5));
        d.insert(5); // duplicate insert must not evict anything
        assert_eq!(d.len(), 3);
        assert!(d.contains(3));
    }
}
