//! RPC substrate: framed JSON over TCP (the paper used RPyC), plus the
//! async multiplexed binary plane layered next to it.
//!
//! * [`frame`] — length-prefixed framing over any `Read + Write` stream.
//! * [`rpc`] — request/response server and client on top of frames, plus
//!   an in-process channel transport so tests and the `--in-proc` mode
//!   run the identical protocol without sockets.
//! * [`mux`] — the readiness-loop multiplexer: one event-loop thread
//!   owns every worker socket, correlation-id frames keep hundreds of
//!   RPCs in flight without parked threads (DESIGN.md §17).
//! * [`backoff`] — capped exponential backoff + jitter for every
//!   reconnecting dialer.

pub mod backoff;
pub mod frame;
pub mod mux;
pub mod rpc;

pub use mux::{Mux, MuxConfig, MuxServer, MuxService};
pub use rpc::{InProcHub, RpcClient, RpcHandler, RpcServer};
