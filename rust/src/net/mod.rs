//! RPC substrate: framed JSON over TCP (the paper used RPyC).
//!
//! * [`frame`] — length-prefixed framing over any `Read + Write` stream.
//! * [`rpc`] — request/response server and client on top of frames, plus
//!   an in-process channel transport so tests and the `--in-proc` mode
//!   run the identical protocol without sockets.

pub mod frame;
pub mod rpc;

pub use rpc::{InProcHub, RpcClient, RpcHandler, RpcServer};
