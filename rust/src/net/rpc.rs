//! Request/response RPC over framed JSON.
//!
//! Server: one OS thread per connection (the worker counts here are
//! single digits — the paper evaluates up to 4 workers + 4 clients — so
//! thread-per-connection is the simplest correct design; the DES handles
//! the thousands-of-events regime instead).
//!
//! Protocol envelope: `{"id": n, "op": "...", ...params}` →
//! `{"id": n, "ok": true, ...result}` or
//! `{"id": n, "ok": false, "error": {"kind": "...", "msg": "..."}}`.
//!
//! Errors are typed end to end: a handler returns
//! [`DqError`], the envelope carries its wire form, and
//! [`RpcClient::call`] decodes it back — so a remote client matches on
//! the same variant the manager raised. Transport-level failures (socket
//! I/O, closed peers, envelope violations) surface as [`DqError::Io`] /
//! [`DqError::Protocol`] locally.
//!
//! [`InProcHub`] provides the identical call interface between threads of
//! one process without sockets — tests and `--in-proc` mode use it.
//!
//! **Dual codec.** [`RpcServer::serve_bin`] sniffs the first four bytes
//! of each accepted connection: the mux magic hands the socket to a
//! lazily-created [`mux::MuxServer`] *accept park* — one readiness-scan
//! thread serving every binary client, shared across all of this
//! server's connections — while anything else is the opening big-endian
//! frame length of a JSON session and stays on the thread-per-connection
//! loop below. The two are unambiguous because the magic decodes as a
//! length far above [`MAX_FRAME`]. JSON stays the debug/fallback path;
//! old peers never see a byte they can't parse — and binary clients now
//! cost zero threads each (DESIGN.md §19).

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::backoff;
use super::frame::{read_frame, write_frame, FrameError, MAX_FRAME};
use super::mux::{self, poll_read_exact, MuxService, PollRead};
use crate::error::DqError;
use crate::wire::{self, Value};

impl From<FrameError> for DqError {
    fn from(e: FrameError) -> Self {
        DqError::Io(e.to_string())
    }
}

/// A request handler: `op` and params in, result fields out (an object),
/// or a typed [`DqError`] that round-trips to the caller.
pub trait RpcHandler: Send + Sync + 'static {
    fn handle(&self, op: &str, params: &Value) -> Result<Value, DqError>;
}

impl<F> RpcHandler for F
where
    F: Fn(&str, &Value) -> Result<Value, DqError> + Send + Sync + 'static,
{
    fn handle(&self, op: &str, params: &Value) -> Result<Value, DqError> {
        self(op, params)
    }
}

/// Thread-per-connection TCP RPC server (JSON sessions); binary
/// sessions are adopted into a shared single-threaded mux park.
pub struct RpcServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// Lazily-created binary accept park — no transport thread is spent
    /// until the first `DQMX` client actually shows up.
    park: Arc<Mutex<Option<mux::MuxServer>>>,
}

impl RpcServer {
    /// Bind and start serving. `addr` may use port 0 for an ephemeral port;
    /// the bound address is available via [`RpcServer::local_addr`].
    pub fn serve<A: ToSocketAddrs>(addr: A, handler: Arc<dyn RpcHandler>) -> std::io::Result<RpcServer> {
        Self::serve_inner(addr, handler, None)
    }

    /// Like [`RpcServer::serve`], but dual-codec: a connection opening
    /// with the mux magic becomes a binary session dispatched through
    /// `service`; everything else speaks framed JSON as before.
    pub fn serve_bin<A: ToSocketAddrs>(
        addr: A,
        handler: Arc<dyn RpcHandler>,
        service: Arc<dyn MuxService>,
    ) -> std::io::Result<RpcServer> {
        Self::serve_inner(addr, handler, Some(service))
    }

    fn serve_inner<A: ToSocketAddrs>(
        addr: A,
        handler: Arc<dyn RpcHandler>,
        service: Option<Arc<dyn MuxService>>,
    ) -> std::io::Result<RpcServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let park: Arc<Mutex<Option<mux::MuxServer>>> = Arc::new(Mutex::new(None));
        let park2 = park.clone();
        let accept_thread = std::thread::Builder::new()
            .name("rpc-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let h = handler.clone();
                            let stop3 = stop2.clone();
                            let svc = service.clone();
                            let prk = park2.clone();
                            let _ = std::thread::Builder::new()
                                .name("rpc-conn".into())
                                .spawn(move || serve_connection(stream, h, stop3, svc, prk));
                        }
                        Err(e) if is_transient_accept(&e) => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(e) => {
                            // A dead listener (EMFILE, EBADF, …) would
                            // otherwise spin-sleep forever; stop cleanly.
                            crate::log_warn!("rpc", "accept failed fatally, listener stops: {e}");
                            break;
                        }
                    }
                }
            })
            .expect("spawn rpc-accept");
        Ok(RpcServer { addr: local, stop, accept_thread: Some(accept_thread), park })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Signal shutdown and join the accept loop (and the binary accept
    /// park, if any `DQMX` client ever caused one to exist).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let park = self.park.lock().unwrap_or_else(|e| e.into_inner()).take();
        drop(park); // MuxServer::drop joins its serve loop
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Accept errors worth retrying (vs a dead listener worth stopping).
fn is_transient_accept(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::Interrupted
            | std::io::ErrorKind::TimedOut
            | std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::ConnectionReset
    )
}

fn serve_connection(
    stream: TcpStream,
    handler: Arc<dyn RpcHandler>,
    stop: Arc<AtomicBool>,
    service: Option<Arc<dyn MuxService>>,
    park: Arc<Mutex<Option<mux::MuxServer>>>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    // Codec sniff — straight off the stream, *before* any buffering, so
    // an adopted socket carries no bytes hidden in a BufReader. The
    // first 4 bytes are either the mux magic or the opening big-endian
    // JSON frame length (the magic is unambiguous — as a length it
    // would exceed MAX_FRAME).
    let mut first = [0u8; 4];
    match poll_read_exact(&mut (&stream), &mut first, &stop) {
        Ok(PollRead::Done) => {}
        _ => return,
    }
    if first == mux::MAGIC {
        if let Some(svc) = service {
            // Hand the socket to the shared binary park (created on the
            // first binary client) and let this thread exit: binary
            // sessions cost zero threads each.
            park.lock()
                .unwrap_or_else(|e| e.into_inner())
                .get_or_insert_with(|| mux::MuxServer::adoptive(svc))
                .adopt(stream, &first);
        }
        // No binary service configured: close; the dialer falls back to
        // JSON exactly as it would against a legacy server.
        return;
    }
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = BufWriter::new(stream);
    // JSON session; `first` is already the first frame's length prefix.
    // Frames are read with poll_read_exact so a 200 ms read-timeout poll
    // mid-frame never discards partial data (`read_exact` leaves the
    // buffer unspecified on error).
    let mut pending_len = Some(first);
    while !stop.load(Ordering::Relaxed) {
        let len_buf = match pending_len.take() {
            Some(b) => b,
            None => {
                let mut b = [0u8; 4];
                match poll_read_exact(&mut reader, &mut b, &stop) {
                    Ok(PollRead::Done) => b,
                    _ => return, // clean EOF, stop, or torn frame
                }
            }
        };
        let len = u32::from_be_bytes(len_buf);
        if len > MAX_FRAME {
            return;
        }
        let mut payload = vec![0u8; len as usize];
        match poll_read_exact(&mut reader, &mut payload, &stop) {
            Ok(PollRead::Done) => {}
            _ => return,
        }
        let req = match std::str::from_utf8(&payload).ok().and_then(|t| wire::parse(t).ok()) {
            Some(v) => v,
            None => return,
        };
        let resp = dispatch(&*handler, &req);
        if write_frame(&mut writer, &resp).is_err() {
            return;
        }
    }
}

fn dispatch(handler: &dyn RpcHandler, req: &Value) -> Value {
    let id = req.get("id").cloned().unwrap_or(Value::Null);
    let op = match req.get("op").and_then(Value::as_str) {
        Some(op) => op,
        None => {
            return Value::obj()
                .with("id", "?")
                .with("ok", false)
                .with("error", DqError::Protocol("missing 'op'".into()).to_wire())
        }
    };
    match handler.handle(op, req) {
        Ok(mut result) => {
            if !matches!(result, Value::Obj(_)) {
                result = Value::obj().with("value", result);
            }
            result.set("id", id);
            result.set("ok", true);
            result
        }
        Err(e) => {
            let mut v = Value::obj().with("ok", false).with("error", e.to_wire());
            v.set("id", id);
            v
        }
    }
}

/// Blocking RPC client; safe for concurrent use (calls serialize on an
/// internal mutex — fine at the message rates the coordinator produces).
pub struct RpcClient {
    inner: Mutex<ClientInner>,
    next_id: AtomicU64,
}

enum ClientInner {
    Tcp { reader: BufReader<TcpStream>, writer: BufWriter<TcpStream> },
    Chan { tx: mpsc::Sender<Value>, rx: mpsc::Receiver<Value> },
}

impl RpcClient {
    /// Connect over TCP, retrying under capped exponential backoff +
    /// jitter for up to `timeout` (the server may still be starting, or
    /// restarting — a transient refusal should not fail the dial).
    pub fn connect<A: ToSocketAddrs + Clone>(addr: A, timeout: Duration) -> Result<RpcClient, DqError> {
        let stream = backoff::retry(
            timeout,
            Duration::from_millis(10),
            Duration::from_millis(500),
            || TcpStream::connect(addr.clone()),
        )
        .map_err(|e| DqError::Io(format!("connect failed: {e}")))?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone().map_err(|e| DqError::Io(e.to_string()))?);
        let writer = BufWriter::new(stream);
        Ok(RpcClient {
            inner: Mutex::new(ClientInner::Tcp { reader, writer }),
            next_id: AtomicU64::new(1),
        })
    }

    /// Issue one call. `params` must be an object; `op` and `id` are
    /// added. A remote failure decodes back into the [`DqError`] the
    /// handler raised.
    pub fn call(&self, op: &str, mut params: Value) -> Result<Value, DqError> {
        if !matches!(params, Value::Obj(_)) {
            return Err(DqError::Protocol("params must be an object".into()));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        params.set("op", op);
        params.set("id", id);
        let mut inner = self.inner.lock().expect("rpc client poisoned");
        let resp = match &mut *inner {
            ClientInner::Tcp { reader, writer } => {
                write_frame(writer, &params)?;
                loop {
                    match read_frame(reader) {
                        Ok(Some(v)) => break v,
                        Ok(None) => return Err(DqError::Io("connection closed".into())),
                        Err(FrameError::Io(e))
                            if matches!(
                                e.kind(),
                                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                            ) =>
                        {
                            continue
                        }
                        Err(e) => return Err(e.into()),
                    }
                }
            }
            ClientInner::Chan { tx, rx } => {
                tx.send(params).map_err(|_| DqError::Io("connection closed".into()))?;
                rx.recv().map_err(|_| DqError::Io("connection closed".into()))?
            }
        };
        let got_id = resp.get("id").and_then(Value::as_u64);
        if got_id != Some(id) {
            return Err(DqError::Protocol(format!("response id mismatch: {got_id:?} != {id}")));
        }
        if resp.get("ok").and_then(Value::as_bool) == Some(true) {
            Ok(resp)
        } else {
            Err(resp
                .get("error")
                .map(DqError::from_wire)
                .unwrap_or_else(|| DqError::Protocol("error response without payload".into())))
        }
    }
}

/// In-process "network": hands out [`RpcClient`]s whose calls are served
/// by a handler thread, exercising the same envelope/dispatch code paths
/// as TCP.
pub struct InProcHub {
    handler: Arc<dyn RpcHandler>,
}

impl InProcHub {
    pub fn new(handler: Arc<dyn RpcHandler>) -> InProcHub {
        InProcHub { handler }
    }

    /// Create a client; a dedicated service thread dispatches its calls.
    pub fn client(&self) -> RpcClient {
        let (req_tx, req_rx) = mpsc::channel::<Value>();
        let (resp_tx, resp_rx) = mpsc::channel::<Value>();
        let handler = self.handler.clone();
        std::thread::Builder::new()
            .name("rpc-inproc".into())
            .spawn(move || {
                while let Ok(req) = req_rx.recv() {
                    let resp = dispatch(&*handler, &req);
                    if resp_tx.send(resp).is_err() {
                        break;
                    }
                }
            })
            .expect("spawn rpc-inproc");
        RpcClient {
            inner: Mutex::new(ClientInner::Chan { tx: req_tx, rx: resp_rx }),
            next_id: AtomicU64::new(1),
        }
    }
}

/// A negotiated connection to a dual-codec peer: the binary mux plane
/// when the peer completed the `DQMX` handshake, framed JSON otherwise.
///
/// Both the manager's worker dial-back and `cluster::tcp::RemoteClient`
/// dial through [`dial_plane`], so "binary-first, JSON-fallback" is one
/// code path, not two reimplementations of the same negotiation.
pub enum Plane {
    /// Binary session on a shared [`mux::Mux`] reactor.
    Bin {
        mux: Arc<mux::Mux>,
        conn: u64,
        /// Negotiated feature bits (`wire::bin::FEAT_*`) — callers gate
        /// push subscriptions on `FEAT_PUSH` here.
        features: u8,
    },
    /// Framed-JSON session (legacy peer, or the mux dial failed).
    Json(Arc<RpcClient>),
}

impl Plane {
    /// Did the dial land on the binary plane?
    pub fn is_binary(&self) -> bool {
        matches!(self, Plane::Bin { .. })
    }
}

/// Dial a dual-codec peer binary-first: try the mux `DQMX` handshake on
/// `mux`'s reactor; if the peer closes or refuses (a JSON-only server, a
/// version-0 peer), fall back to a plain [`RpcClient`] dial with
/// `json_timeout`. The mux attempt is bounded by the mux's own
/// `connect_timeout`, so a legacy server costs one quick failed
/// handshake, not a stall.
pub fn dial_plane<A: ToSocketAddrs + Clone>(
    mux: &Arc<mux::Mux>,
    addr: A,
    json_timeout: Duration,
) -> Result<Plane, DqError> {
    match mux.connect(addr.clone()) {
        Ok(conn) => Ok(Plane::Bin {
            mux: mux.clone(),
            conn: conn.id,
            features: conn.negotiated.features,
        }),
        Err(e) => {
            crate::log_warn!("rpc", "binary dial failed ({e}); falling back to JSON");
            let rpc = RpcClient::connect(addr, json_timeout)?;
            Ok(Plane::Json(Arc::new(rpc)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_handler() -> Arc<dyn RpcHandler> {
        Arc::new(|op: &str, params: &Value| -> Result<Value, DqError> {
            match op {
                "echo" => Ok(Value::obj().with("echoed", params.get("msg").cloned().unwrap_or(Value::Null))),
                "add" => {
                    let a = params.req_f64("a")?;
                    let b = params.req_f64("b")?;
                    Ok(Value::obj().with("sum", a + b))
                }
                "fail" => Err(DqError::Io("deliberate failure".to_string())),
                "cancelled" => Err(DqError::Cancelled("bank 9 cancelled".to_string())),
                _ => Err(DqError::Protocol(format!("unknown op {op}"))),
            }
        })
    }

    #[test]
    fn tcp_round_trip() {
        let server = RpcServer::serve("127.0.0.1:0", echo_handler()).unwrap();
        let client = RpcClient::connect(server.local_addr(), Duration::from_secs(2)).unwrap();
        let resp = client.call("add", Value::obj().with("a", 2.0).with("b", 40.0)).unwrap();
        assert_eq!(resp.req_f64("sum").unwrap(), 42.0);
    }

    #[test]
    fn tcp_many_sequential_calls() {
        let server = RpcServer::serve("127.0.0.1:0", echo_handler()).unwrap();
        let client = RpcClient::connect(server.local_addr(), Duration::from_secs(2)).unwrap();
        for i in 0..50 {
            let r = client
                .call("add", Value::obj().with("a", i as f64).with("b", 1.0))
                .unwrap();
            assert_eq!(r.req_f64("sum").unwrap(), i as f64 + 1.0);
        }
    }

    #[test]
    fn remote_error_round_trips_typed() {
        let server = RpcServer::serve("127.0.0.1:0", echo_handler()).unwrap();
        let client = RpcClient::connect(server.local_addr(), Duration::from_secs(2)).unwrap();
        match client.call("fail", Value::obj()) {
            Err(DqError::Io(msg)) => assert!(msg.contains("deliberate")),
            other => panic!("expected typed Io error, got {other:?}"),
        }
        match client.call("cancelled", Value::obj()) {
            Err(DqError::Cancelled(msg)) => assert!(msg.contains("bank 9")),
            other => panic!("expected typed Cancelled error, got {other:?}"),
        }
    }

    #[test]
    fn unknown_op_is_protocol_error() {
        let server = RpcServer::serve("127.0.0.1:0", echo_handler()).unwrap();
        let client = RpcClient::connect(server.local_addr(), Duration::from_secs(2)).unwrap();
        assert!(matches!(client.call("nope", Value::obj()), Err(DqError::Protocol(_))));
    }

    #[test]
    fn missing_field_is_protocol_error() {
        // Value::req_* string errors enter the taxonomy as Protocol.
        let server = RpcServer::serve("127.0.0.1:0", echo_handler()).unwrap();
        let client = RpcClient::connect(server.local_addr(), Duration::from_secs(2)).unwrap();
        match client.call("add", Value::obj().with("a", 1.0)) {
            Err(DqError::Protocol(msg)) => assert!(msg.contains('b')),
            other => panic!("expected protocol error, got {other:?}"),
        }
    }

    #[test]
    fn multiple_clients_one_server() {
        let server = RpcServer::serve("127.0.0.1:0", echo_handler()).unwrap();
        let addr = server.local_addr();
        let threads: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let client = RpcClient::connect(addr, Duration::from_secs(2)).unwrap();
                    for i in 0..20 {
                        let r = client
                            .call("add", Value::obj().with("a", t as f64).with("b", i as f64))
                            .unwrap();
                        assert_eq!(r.req_f64("sum").unwrap(), (t + i) as f64);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn inproc_matches_tcp_semantics() {
        let hub = InProcHub::new(echo_handler());
        let client = hub.client();
        let r = client.call("echo", Value::obj().with("msg", "hi")).unwrap();
        assert_eq!(r.get("echoed").unwrap().as_str(), Some("hi"));
        assert!(matches!(client.call("fail", Value::obj()), Err(DqError::Io(_))));
    }

    #[test]
    fn server_shutdown_unblocks() {
        let mut server = RpcServer::serve("127.0.0.1:0", echo_handler()).unwrap();
        server.shutdown(); // must not hang
    }

    #[test]
    fn dual_codec_server_speaks_json_and_binary() {
        let svc: Arc<dyn MuxService> =
            Arc::new(|_op: u32, payload: &[u8]| -> Result<Vec<u8>, DqError> {
                Ok(payload.to_vec())
            });
        let server = RpcServer::serve_bin("127.0.0.1:0", echo_handler(), svc).unwrap();
        // JSON clients are served exactly as before…
        let client = RpcClient::connect(server.local_addr(), Duration::from_secs(2)).unwrap();
        let r = client.call("add", Value::obj().with("a", 1.0).with("b", 2.0)).unwrap();
        assert_eq!(r.req_f64("sum").unwrap(), 3.0);
        // …and a mux dialer negotiates a binary session on the same port.
        let m = mux::Mux::new(mux::MuxConfig::default());
        let conn = m.connect(server.local_addr()).unwrap();
        assert_eq!(m.call(conn.id, 1, b"abc".to_vec()).unwrap(), b"abc");
    }

    #[test]
    fn mux_dial_against_json_only_server_fails_cleanly() {
        // A server without a binary service closes on the magic; the
        // dialer gets a typed error and can fall back to JSON.
        let server = RpcServer::serve("127.0.0.1:0", echo_handler()).unwrap();
        let m = mux::Mux::new(mux::MuxConfig::default());
        assert!(m.connect(server.local_addr()).is_err());
    }

    #[test]
    fn dial_plane_negotiates_binary_against_dual_codec_server() {
        let svc: Arc<dyn MuxService> =
            Arc::new(|_op: u32, payload: &[u8]| -> Result<Vec<u8>, DqError> {
                Ok(payload.to_vec())
            });
        let server = RpcServer::serve_bin("127.0.0.1:0", echo_handler(), svc).unwrap();
        let m = mux::Mux::new(mux::MuxConfig::default());
        let plane = dial_plane(&m, server.local_addr(), Duration::from_secs(2)).unwrap();
        assert!(plane.is_binary());
        match plane {
            Plane::Bin { mux, conn, features } => {
                assert_eq!(features, crate::wire::bin::FEAT_ALL);
                assert_eq!(mux.call(conn, 1, b"xy".to_vec()).unwrap(), b"xy");
            }
            Plane::Json(_) => unreachable!(),
        }
    }

    #[test]
    fn dial_plane_falls_back_to_json_against_legacy_server() {
        let server = RpcServer::serve("127.0.0.1:0", echo_handler()).unwrap();
        let m = mux::Mux::new(mux::MuxConfig::default());
        let plane = dial_plane(&m, server.local_addr(), Duration::from_secs(2)).unwrap();
        assert!(!plane.is_binary());
        match plane {
            Plane::Json(rpc) => {
                let r = rpc.call("add", Value::obj().with("a", 20.0).with("b", 22.0)).unwrap();
                assert_eq!(r.req_f64("sum").unwrap(), 42.0);
            }
            Plane::Bin { .. } => unreachable!(),
        }
    }
}
