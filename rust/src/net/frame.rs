//! Length-prefixed message framing.
//!
//! Wire layout: 4-byte big-endian payload length, then that many bytes of
//! UTF-8 JSON. A hard size cap protects both sides from corrupt frames.

use std::io::{Read, Write};

use crate::wire::{self, Value};

/// Maximum accepted frame payload (16 MiB) — a full 32-circuit bank of
/// q=7 parameters is ~100 KiB, so this is generous but bounded.
pub const MAX_FRAME: u32 = 16 * 1024 * 1024;

/// Framing/decoding failure.
#[derive(Debug)]
pub enum FrameError {
    Io(std::io::Error),
    TooLarge(u32),
    BadJson(String),
    BadUtf8,
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "frame io error: {e}"),
            FrameError::TooLarge(n) => write!(f, "frame of {n} bytes exceeds cap {MAX_FRAME}"),
            FrameError::BadJson(e) => write!(f, "frame payload is not valid json: {e}"),
            FrameError::BadUtf8 => write!(f, "frame payload is not utf-8"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e)
    }
}

/// Write one value as a frame and flush.
pub fn write_frame<W: Write>(w: &mut W, v: &Value) -> Result<(), FrameError> {
    let payload = wire::to_string(v);
    let bytes = payload.as_bytes();
    if bytes.len() as u64 > MAX_FRAME as u64 {
        return Err(FrameError::TooLarge(bytes.len() as u32));
    }
    w.write_all(&(bytes.len() as u32).to_be_bytes())?;
    w.write_all(bytes)?;
    w.flush()?;
    Ok(())
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Value>, FrameError> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(FrameError::TooLarge(len));
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    let text = std::str::from_utf8(&buf).map_err(|_| FrameError::BadUtf8)?;
    wire::parse(text).map(Some).map_err(|e| FrameError::BadJson(e.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn round_trip_single() {
        let v = Value::obj().with("op", "heartbeat").with("worker", 3u64);
        let mut buf = Vec::new();
        write_frame(&mut buf, &v).unwrap();
        let mut cur = Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap(), Some(v));
        assert_eq!(read_frame(&mut cur).unwrap(), None); // clean EOF
    }

    #[test]
    fn round_trip_multiple() {
        let mut buf = Vec::new();
        for i in 0..10u64 {
            write_frame(&mut buf, &Value::obj().with("i", i)).unwrap();
        }
        let mut cur = Cursor::new(buf);
        for i in 0..10u64 {
            let v = read_frame(&mut cur).unwrap().unwrap();
            assert_eq!(v.req_u64("i").unwrap(), i);
        }
        assert_eq!(read_frame(&mut cur).unwrap(), None);
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_be_bytes());
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::TooLarge(_))));
    }

    #[test]
    fn truncated_payload_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&100u32.to_be_bytes());
        buf.extend_from_slice(b"short");
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Io(_))));
    }

    #[test]
    fn corrupt_json_detected() {
        let mut buf = Vec::new();
        let payload = b"{not json";
        buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
        buf.extend_from_slice(payload);
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::BadJson(_))));
    }
}
