//! Non-distributed baseline (the paper's comparison point).
//!
//! "We initially compare its accuracy with the non-distributed version to
//! verify its effectiveness" (§IV-B). The baseline is Algorithm 1 run
//! against a single local simulator with no co-Manager, no RPC, and no
//! concurrency — exactly what QuClassi does on one machine.

use crate::circuit::QuClassiConfig;
use crate::data::Dataset;
use crate::error::DqError;
use crate::model::exec::{CountingExecutor, QsimExecutor};
use crate::model::{QuClassiModel, TrainConfig, TrainReport, Trainer};
use crate::util::Rng;

/// Result of one baseline run.
#[derive(Debug, Clone)]
pub struct BaselineResult {
    pub report: TrainReport,
    pub circuits_executed: u64,
}

/// Train the QuClassi classifier on one machine (no distribution).
pub fn train_single_machine(
    config: QuClassiConfig,
    dataset: &Dataset,
    train_config: TrainConfig,
    model_seed: u64,
) -> Result<BaselineResult, DqError> {
    let mut rng = Rng::new(model_seed);
    let mut model = QuClassiModel::new(config, &mut rng);
    let exec = CountingExecutor::new(QsimExecutor);
    let trainer = Trainer::new(train_config);
    let report = trainer.train(&mut model, dataset, &exec)?;
    Ok(BaselineResult { report, circuits_executed: exec.circuits() })
}

/// Accuracy comparison row: distributed vs non-distributed (paper §IV-B
/// reports deltas under 2%).
#[derive(Debug, Clone)]
pub struct AccuracyComparison {
    pub pair: (u8, u8),
    pub distributed_acc: f64,
    pub baseline_acc: f64,
}

impl AccuracyComparison {
    pub fn delta(&self) -> f64 {
        (self.distributed_acc - self.baseline_acc).abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::optimizer::Optimizer;
    use crate::model::quclassi::LossKind;

    #[test]
    fn baseline_trains_and_counts() {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let ds = Dataset::binary_pair(None, 1, 5, 10, 3);
        let tc = TrainConfig {
            epochs: 3,
            optimizer: Optimizer::adam(0.1),
            train_classical: true,
            classical_lr_scale: 0.1,
            seed: 11,
            early_stop_acc: None,
            loss: LossKind::Discriminative,
        };
        let result = train_single_machine(cfg, &ds, tc, 21).unwrap();
        assert_eq!(result.report.epochs.len(), 3);
        assert!(result.circuits_executed > 0);
        assert!(result.report.final_train_accuracy() > 0.5);
    }

    #[test]
    fn comparison_delta() {
        let c = AccuracyComparison { pair: (3, 9), distributed_acc: 0.975, baseline_acc: 0.99 };
        assert!((c.delta() - 0.015).abs() < 1e-12);
    }
}
