//! Quantum worker: the process that actually executes circuits.
//!
//! A worker advertises a maximum qubit count (`MR`), executes circuit
//! batches through its backend (PJRT artifacts or the Rust simulator),
//! reports classical resource usage (`CRU`) and active circuits via
//! heartbeats, and serves `execute` RPCs from the co-Manager.

pub mod backend;
pub mod cru;
pub mod service;

pub use backend::WorkerBackend;
pub use cru::{CruProbe, LoadModelCru, ProcStatCru};
pub use service::{WorkerHandle, WorkerOptions};
