//! Classical Resource Usage probes (`CRU_{w_i}(t) = sys_{w_i}` in
//! Algorithm 2).
//!
//! The paper queries system CPU usage on each worker VM. We provide a
//! real probe (`/proc` on Linux) for distributed deployments and a
//! deterministic load-model probe for in-proc and simulated runs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Samples this worker's classical resource usage in [0, 1].
pub trait CruProbe: Send + Sync {
    fn sample(&self) -> f64;
}

/// Real probe: 1-minute load average over core count (Linux `/proc`).
pub struct ProcStatCru;

impl CruProbe for ProcStatCru {
    fn sample(&self) -> f64 {
        let text = match std::fs::read_to_string("/proc/loadavg") {
            Ok(t) => t,
            Err(_) => return 0.0,
        };
        let load: f64 = text.split_whitespace().next().and_then(|s| s.parse().ok()).unwrap_or(0.0);
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64;
        (load / cores).clamp(0.0, 1.0)
    }
}

/// Deterministic model: CRU grows with the number of circuits the worker
/// is currently executing (each circuit contributes `per_circuit`).
#[derive(Clone)]
pub struct LoadModelCru {
    active: Arc<AtomicUsize>,
    per_circuit: f64,
    baseline: f64,
}

impl LoadModelCru {
    pub fn new(per_circuit: f64, baseline: f64) -> LoadModelCru {
        LoadModelCru { active: Arc::new(AtomicUsize::new(0)), per_circuit, baseline }
    }

    /// Counter handle shared with the executor loop.
    pub fn counter(&self) -> Arc<AtomicUsize> {
        self.active.clone()
    }
}

impl CruProbe for LoadModelCru {
    fn sample(&self) -> f64 {
        let n = self.active.load(Ordering::Relaxed) as f64;
        (self.baseline + n * self.per_circuit).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proc_probe_in_unit_range() {
        let p = ProcStatCru;
        let v = p.sample();
        assert!((0.0..=1.0).contains(&v));
    }

    #[test]
    fn load_model_tracks_active_circuits() {
        let p = LoadModelCru::new(0.2, 0.1);
        assert!((p.sample() - 0.1).abs() < 1e-12);
        p.counter().store(3, Ordering::Relaxed);
        assert!((p.sample() - 0.7).abs() < 1e-12);
        p.counter().store(100, Ordering::Relaxed);
        assert_eq!(p.sample(), 1.0); // clamped
    }
}
