//! Worker execution backends: PJRT artifacts or the Rust simulator.

use std::path::Path;

use crate::circuit::QuClassiConfig;
use crate::error::DqError;
use crate::model::exec::{self, CircuitExecutor, CircuitPair, ParallelQsimExecutor, QsimExecutor};
use crate::qsim::NoiseModel;
use crate::runtime::PjrtEngine;

/// Which engine executes circuits on this worker.
pub enum WorkerBackend {
    /// AOT-compiled JAX/Pallas artifacts via PJRT (production path).
    Pjrt(PjrtEngine),
    /// Pure-Rust statevector simulation (fallback / tests).
    Qsim,
    /// Rust simulation striped across an internal thread pool — the
    /// worker-side throughput lever (DESIGN.md §11). Bitwise identical
    /// to [`WorkerBackend::Qsim`], parallel wall-clock.
    ParallelQsim(ParallelQsimExecutor),
    /// Rust simulation with trajectory noise (extension; DESIGN.md §10).
    NoisyQsim(NoiseModel, u64),
}

impl WorkerBackend {
    /// PJRT if artifacts are present, otherwise the simulator sized to
    /// the host's thread budget.
    pub fn auto(artifact_dir: &Path) -> WorkerBackend {
        Self::auto_with_threads(artifact_dir, 0)
    }

    /// [`WorkerBackend::auto`] with an explicit simulator thread budget
    /// (`0` = detect from the host; `1` = the serial backend).
    pub fn auto_with_threads(artifact_dir: &Path, threads: usize) -> WorkerBackend {
        if artifact_dir.join("manifest.json").exists() {
            match PjrtEngine::load(artifact_dir) {
                Ok(engine) => return WorkerBackend::Pjrt(engine),
                Err(e) => {
                    crate::log_warn!("worker", "pjrt load failed ({e}); using qsim backend");
                }
            }
        }
        let threads = if threads == 0 { exec::detect_threads() } else { threads };
        if threads > 1 {
            WorkerBackend::ParallelQsim(ParallelQsimExecutor::new(threads))
        } else {
            WorkerBackend::Qsim
        }
    }

    /// The backend's internal thread budget (1 for serial backends; the
    /// CRU-reported capacity the co-Manager sizes dispatch batches by).
    pub fn threads(&self) -> usize {
        match self {
            WorkerBackend::ParallelQsim(e) => e.threads(),
            _ => 1,
        }
    }

    /// Execute a batch of circuits through this backend.
    pub fn execute(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        match self {
            WorkerBackend::Pjrt(engine) => Ok(engine.execute(config, pairs)?),
            WorkerBackend::Qsim => QsimExecutor.execute_bank(config, pairs),
            WorkerBackend::ParallelQsim(pool) => pool.execute_bank(config, pairs),
            WorkerBackend::NoisyQsim(noise, seed) => {
                // Trajectory simulation with per-gate Pauli noise. The
                // trajectory stream is derived from *every* circuit in
                // the batch so repeated calls see fresh (but
                // reproducible) noise draws — hashing only the first
                // pair would replay an identical noise stream for any
                // two batches sharing pair 0.
                let mut hash = 0xcbf2_9ce4_8422_2325u64 ^ seed;
                for (t, d) in pairs.iter() {
                    for x in t.iter().chain(d.iter()) {
                        hash = (hash ^ x.to_bits() as u64).wrapping_mul(0x1000_0000_01b3);
                    }
                }
                let mut rng = crate::util::Rng::new(hash);
                pairs
                    .iter()
                    .map(|(thetas, data)| {
                        let gates = crate::circuit::build_quclassi(config, thetas, data);
                        let mut st = crate::qsim::State::zero(config.qubits);
                        for g in &gates {
                            st.apply_gate(g);
                            noise.apply_after(&mut st, g, &mut rng);
                        }
                        let p0 = noise.corrupt_prob_zero(st.prob_zero(0));
                        Ok((2.0 * p0 - 1.0) as f32)
                    })
                    .collect()
            }
        }
    }

    /// Short backend identifier for logs and registration.
    pub fn name(&self) -> &'static str {
        match self {
            WorkerBackend::Pjrt(_) => "pjrt",
            WorkerBackend::Qsim => "qsim",
            WorkerBackend::ParallelQsim(_) => "qsim-par",
            WorkerBackend::NoisyQsim(..) => "noisy-qsim",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn pairs(cfg: &QuClassiConfig, n: usize) -> Vec<CircuitPair> {
        let mut rng = Rng::new(4);
        (0..n)
            .map(|_| {
                (
                    (0..cfg.n_params()).map(|_| rng.f32() * 2.0).collect(),
                    (0..cfg.n_features()).map(|_| rng.f32() * 2.0).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn qsim_backend_executes() {
        let cfg = QuClassiConfig::new(5, 3).unwrap();
        let b = WorkerBackend::Qsim;
        let fids = b.execute(&cfg, &pairs(&cfg, 4)).unwrap();
        assert_eq!(fids.len(), 4);
        assert!(fids.iter().all(|f| (-1e-5..=1.0 + 1e-5).contains(&(*f as f64))));
    }

    #[test]
    fn noiseless_noisy_backend_matches_qsim() {
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let ps = pairs(&cfg, 3);
        let clean = WorkerBackend::Qsim.execute(&cfg, &ps).unwrap();
        let noisy = WorkerBackend::NoisyQsim(NoiseModel::NOISELESS, 1).execute(&cfg, &ps).unwrap();
        for (a, b) in clean.iter().zip(noisy.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn noise_shifts_fidelities() {
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let ps = pairs(&cfg, 8);
        let clean = WorkerBackend::Qsim.execute(&cfg, &ps).unwrap();
        let noisy = WorkerBackend::NoisyQsim(
            NoiseModel { p1: 0.2, p2: 0.3, readout: 0.05 },
            7,
        )
        .execute(&cfg, &ps)
        .unwrap();
        let diff: f32 =
            clean.iter().zip(noisy.iter()).map(|(a, b)| (a - b).abs()).sum::<f32>();
        assert!(diff > 1e-3, "noise had no effect");
    }

    #[test]
    fn noise_stream_depends_on_every_pair() {
        // Regression: the trajectory hash once read only pairs[0], so two
        // batches sharing pair 0 replayed an identical noise stream.
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let ps = pairs(&cfg, 2);
        let mut alt = ps.clone();
        alt[1].0[0] += 1.0; // same pair 0, different pair 1
        let noise = NoiseModel { p1: 0.2, p2: 0.3, readout: 0.0 };
        let a = WorkerBackend::NoisyQsim(noise, 7).execute(&cfg, &ps).unwrap();
        let b = WorkerBackend::NoisyQsim(noise, 7).execute(&cfg, &alt).unwrap();
        assert_ne!(a[0], b[0], "noise stream must depend on later pairs too");
    }

    #[test]
    fn auto_falls_back_without_artifacts() {
        let b = WorkerBackend::auto(Path::new("/nonexistent/dir"));
        assert!(b.name().starts_with("qsim"), "unexpected backend {}", b.name());
        assert!(b.threads() >= 1);
        let serial = WorkerBackend::auto_with_threads(Path::new("/nonexistent/dir"), 1);
        assert_eq!(serial.name(), "qsim");
        assert_eq!(serial.threads(), 1);
    }

    #[test]
    fn parallel_backend_matches_serial_bitwise() {
        let cfg = QuClassiConfig::new(7, 2).unwrap();
        let ps = pairs(&cfg, 9);
        let serial = WorkerBackend::Qsim.execute(&cfg, &ps).unwrap();
        let parallel = WorkerBackend::auto_with_threads(Path::new("/nonexistent/dir"), 4);
        assert_eq!(parallel.name(), "qsim-par");
        assert_eq!(parallel.threads(), 4);
        assert_eq!(parallel.execute(&cfg, &ps).unwrap(), serial);
    }
}
