//! Worker process runtime: execute-RPC server + registration + heartbeat
//! loop (the distributed deployment path).
//!
//! Manager-side, each registered worker gets a dedicated outbox
//! dispatcher (DESIGN.md §13): `execute` RPCs arrive one batch at a time
//! from that thread, and each heartbeat doubles as a scheduling event
//! (a fresh CRU sample can change Algorithm 2's ranking immediately,
//! not at the next poll tick).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::backend::WorkerBackend;
use super::cru::{CruProbe, LoadModelCru};
use crate::circuit::QuClassiConfig;
use crate::coordinator::job::CircuitJob;
use crate::error::DqError;
use crate::net::{MuxService, RpcClient, RpcServer};
use crate::wire::{bin, Value};

/// Worker startup options.
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// `MR` — advertised maximum qubits.
    pub max_qubits: usize,
    /// Where the AOT artifacts live (PJRT backend when present).
    pub artifact_dir: PathBuf,
    /// Heartbeat period in seconds (paper default: 5).
    pub heartbeat_period: f64,
    /// Listen address for execute RPCs ("127.0.0.1:0" = ephemeral).
    pub listen: String,
    /// Simulator thread budget (`0` = detect from the host, `1` =
    /// serial). Reported to the manager at registration so dispatch
    /// batches track real parallelism (DESIGN.md §11).
    pub threads: usize,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions {
            max_qubits: 5,
            artifact_dir: PathBuf::from("artifacts"),
            heartbeat_period: 5.0,
            listen: "127.0.0.1:0".to_string(),
            threads: 0,
        }
    }
}

/// The worker's binary-plane request handler (mux park service).
struct WorkerBinService {
    backend: Arc<WorkerBackend>,
    active: Arc<AtomicUsize>,
}

impl MuxService for WorkerBinService {
    fn handle(&self, op: u32, payload: &[u8]) -> Result<Vec<u8>, DqError> {
        if op != bin::OP_EXECUTE {
            return Err(DqError::Protocol(format!("worker: unknown bin op {op}")));
        }
        let jobs = bin::decode_jobs(payload)?;
        let mut config: Option<QuClassiConfig> = None;
        let mut pairs = Vec::with_capacity(jobs.len());
        for job in jobs {
            if let Some(c) = config {
                if c != job.config {
                    return Err(DqError::Protocol("mixed configs in one execute".to_string()));
                }
            }
            config = Some(job.config);
            pairs.push((job.thetas, job.data));
        }
        let config = config.ok_or_else(|| DqError::Protocol("empty execute".to_string()))?;
        self.active.fetch_add(pairs.len(), Ordering::Relaxed);
        let result = self.backend.execute(&config, &pairs);
        self.active.fetch_sub(pairs.len(), Ordering::Relaxed);
        Ok(bin::encode_fids(&result?))
    }

    /// Simulations block for arbitrarily long: run them off the park's
    /// transport thread so other connections (and re-adoptions after a
    /// socket flap) stay live mid-execute.
    fn defer(&self, op: u32) -> bool {
        op == bin::OP_EXECUTE
    }
}

/// Handle to a running worker (drop/stop to shut down).
pub struct WorkerHandle {
    pub worker_id: u64,
    pub listen_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    _server: RpcServer,
    heartbeat_thread: Option<std::thread::JoinHandle<()>>,
}

impl WorkerHandle {
    /// Start a worker: serve `execute`, register with the manager at
    /// `manager_addr`, and heartbeat until stopped.
    pub fn start(manager_addr: &str, opts: WorkerOptions) -> Result<WorkerHandle, DqError> {
        let backend = Arc::new(WorkerBackend::auto_with_threads(&opts.artifact_dir, opts.threads));
        let active = Arc::new(AtomicUsize::new(0));
        let cru = LoadModelCru::new(1.0 / opts.max_qubits.max(1) as f64, 0.05);
        // share the executing-circuit counter with the CRU model
        let cru_counter = cru.counter();

        // --- execute RPC server ---
        let backend2 = backend.clone();
        let active2 = active.clone();
        let handler = move |op: &str, params: &Value| -> Result<Value, DqError> {
            match op {
                "execute" => {
                    let jobs = params.req_arr("circuits")?;
                    let mut config: Option<QuClassiConfig> = None;
                    let mut pairs = Vec::with_capacity(jobs.len());
                    for j in jobs {
                        let job = CircuitJob::from_wire(j)?;
                        if let Some(c) = config {
                            if c != job.config {
                                return Err(DqError::Protocol(
                                    "mixed configs in one execute".to_string(),
                                ));
                            }
                        }
                        config = Some(job.config);
                        pairs.push((job.thetas, job.data));
                    }
                    let config =
                        config.ok_or_else(|| DqError::Protocol("empty execute".to_string()))?;
                    active2.fetch_add(pairs.len(), Ordering::Relaxed);
                    let result = backend2.execute(&config, &pairs);
                    active2.fetch_sub(pairs.len(), Ordering::Relaxed);
                    let fids = result?;
                    Ok(Value::obj().with("fids", fids.as_slice()))
                }
                "ping" => Ok(Value::obj().with("pong", true)),
                other => Err(DqError::Protocol(format!("worker: unknown op '{other}'"))),
            }
        };
        // Binary-plane service for the same endpoint: a manager that
        // negotiates the mux handshake dispatches `execute` through
        // wire/bin; a JSON manager is served by `handler` above. Same
        // validation rules on both planes. `execute` is deferred so a
        // long simulation never stalls the park's transport thread —
        // and its reply rides the session out-queue, which parks across
        // a connection flap and replays after the in-place reconnect.
        let bin_service: Arc<dyn MuxService> = Arc::new(WorkerBinService {
            backend: backend.clone(),
            active: active.clone(),
        });
        let server = RpcServer::serve_bin(opts.listen.as_str(), Arc::new(handler), bin_service)
            .map_err(|e| DqError::Io(format!("worker listen: {e}")))?;
        let listen_addr = server.local_addr();

        // keep CRU counter synced with active executions
        {
            let active3 = active.clone();
            let counter = cru_counter.clone();
            std::thread::Builder::new()
                .name("worker-cru-sync".into())
                .spawn(move || loop {
                    counter.store(active3.load(Ordering::Relaxed), Ordering::Relaxed);
                    std::thread::sleep(Duration::from_millis(100));
                })
                .map_err(|e| DqError::Io(e.to_string()))?;
        }

        // --- register with the manager ---
        let client = RpcClient::connect(manager_addr, Duration::from_secs(5))
            .map_err(|e| DqError::Io(format!("connect manager: {e}")))?;
        let resp = client
            .call(
                "register",
                Value::obj()
                    .with("max_qubits", opts.max_qubits)
                    .with("addr", listen_addr.to_string())
                    .with("cru", cru.sample())
                    .with("threads", backend.threads()),
            )?;
        let worker_id = resp.req_u64("worker_id")?;
        crate::log_info!(
            "worker",
            "registered as w{worker_id} (MR={}, backend={}, threads={}, listening {listen_addr})",
            opts.max_qubits,
            backend.name(),
            backend.threads()
        );

        // --- heartbeat loop ---
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let period = Duration::from_secs_f64(opts.heartbeat_period);
        let heartbeat_thread = std::thread::Builder::new()
            .name(format!("heartbeat-w{worker_id}"))
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    let _ = client.call(
                        "heartbeat",
                        Value::obj().with("worker_id", worker_id).with("cru", cru.sample()),
                    );
                    // sleep in small steps so stop is responsive
                    let mut slept = Duration::ZERO;
                    while slept < period && !stop2.load(Ordering::Relaxed) {
                        let step = Duration::from_millis(50).min(period - slept);
                        std::thread::sleep(step);
                        slept += step;
                    }
                }
            })
            .map_err(|e| DqError::Io(e.to_string()))?;

        Ok(WorkerHandle {
            worker_id,
            listen_addr,
            stop,
            _server: server,
            heartbeat_thread: Some(heartbeat_thread),
        })
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.heartbeat_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Stand-in manager that accepts register/heartbeat (integration with
    /// the real manager lives in cluster::tcp tests).
    fn fake_manager() -> RpcServer {
        let handler = |op: &str, _params: &Value| -> Result<Value, DqError> {
            match op {
                "register" => Ok(Value::obj().with("worker_id", 7u64)),
                "heartbeat" => Ok(Value::obj()),
                other => Err(DqError::Protocol(format!("unexpected {other}"))),
            }
        };
        RpcServer::serve("127.0.0.1:0", Arc::new(handler)).unwrap()
    }

    #[test]
    fn worker_registers_and_serves_execute() {
        let mgr = fake_manager();
        let opts = WorkerOptions {
            max_qubits: 5,
            artifact_dir: PathBuf::from("/nonexistent"), // force qsim
            heartbeat_period: 0.1,
            listen: "127.0.0.1:0".to_string(),
            threads: 2,
        };
        let mut handle = WorkerHandle::start(&mgr.local_addr().to_string(), opts).unwrap();
        assert_eq!(handle.worker_id, 7);

        // call the worker's execute endpoint like the manager would
        let client =
            RpcClient::connect(handle.listen_addr, Duration::from_secs(2)).unwrap();
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let job = CircuitJob {
            id: 1,
            client: 1,
            bank: 1,
            index: 0,
            config: cfg,
            thetas: vec![0.3; 4],
            data: vec![0.7; 4],
        };
        let resp = client
            .call("execute", Value::obj().with("circuits", vec![job.to_wire()]))
            .unwrap();
        let fids = resp.req_f32_vec("fids").unwrap();
        assert_eq!(fids.len(), 1);
        let want = crate::circuit::builder::simulate_fidelity(&cfg, &[0.3; 4], &[0.7; 4]);
        assert!((fids[0] - want).abs() < 1e-6);
        handle.stop();
    }

    #[test]
    fn execute_rejects_mixed_configs() {
        let mgr = fake_manager();
        let opts = WorkerOptions {
            artifact_dir: PathBuf::from("/nonexistent"),
            heartbeat_period: 0.5,
            ..Default::default()
        };
        let mut handle = WorkerHandle::start(&mgr.local_addr().to_string(), opts).unwrap();
        let client = RpcClient::connect(handle.listen_addr, Duration::from_secs(2)).unwrap();
        let j1 = CircuitJob {
            id: 1,
            client: 1,
            bank: 1,
            index: 0,
            config: QuClassiConfig::new(5, 1).unwrap(),
            thetas: vec![0.0; 4],
            data: vec![0.0; 4],
        };
        let j2 = CircuitJob {
            id: 2,
            client: 1,
            bank: 1,
            index: 1,
            config: QuClassiConfig::new(7, 1).unwrap(),
            thetas: vec![0.0; 6],
            data: vec![0.0; 6],
        };
        let err = client
            .call("execute", Value::obj().with("circuits", vec![j1.to_wire(), j2.to_wire()]))
            .unwrap_err();
        assert!(err.to_string().contains("mixed configs"));
        handle.stop();
    }
}
