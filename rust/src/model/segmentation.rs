//! Task Segmentation (paper §III-A, Fig. 2) + trainable conv filters.
//!
//! "Based on the predefined subtask unit, such as convolutional filter
//! size, the Task Segmentation module decomposes the original data into
//! smaller sections." An image is cut into `w x w` windows at stride `s`
//! (paper settings: w = 4, s = 2, nF = 4 filters); each filter produces a
//! feature map over the windows, which is flattened and fed to the dense
//! layer (Algorithm 1 lines 8-10).

#[cfg(test)]
use crate::data::IMG_SIDE;
use crate::util::Rng;

/// Window segmentation geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segmentation {
    pub width: usize,
    pub stride: usize,
}

impl Segmentation {
    /// The paper's settings: filter width 4, stride 2.
    pub fn paper() -> Segmentation {
        Segmentation { width: 4, stride: 2 }
    }

    /// Number of window positions per image side.
    pub fn out_side(&self, img_side: usize) -> usize {
        (img_side - self.width) / self.stride + 1
    }

    /// Total windows per image.
    pub fn n_windows(&self, img_side: usize) -> usize {
        let o = self.out_side(img_side);
        o * o
    }

    /// Extract all windows (each `width*width` values, row-major).
    pub fn windows(&self, image: &[f32], img_side: usize) -> Vec<Vec<f32>> {
        let o = self.out_side(img_side);
        let mut out = Vec::with_capacity(o * o);
        for wy in 0..o {
            for wx in 0..o {
                let mut window = Vec::with_capacity(self.width * self.width);
                for dy in 0..self.width {
                    for dx in 0..self.width {
                        let y = wy * self.stride + dy;
                        let x = wx * self.stride + dx;
                        window.push(image[y * img_side + x]);
                    }
                }
                out.push(window);
            }
        }
        out
    }
}

/// A bank of trainable convolution filters over the segmentation grid.
#[derive(Debug, Clone)]
pub struct ConvFilters {
    pub seg: Segmentation,
    pub n_filters: usize,
    /// kernels[f] is a `width*width` kernel.
    pub kernels: Vec<Vec<f32>>,
    pub bias: Vec<f32>,
}

impl ConvFilters {
    /// Paper settings: 4 filters of width 4, stride 2, random init.
    pub fn paper(rng: &mut Rng) -> ConvFilters {
        ConvFilters::new(Segmentation::paper(), 4, rng)
    }

    pub fn new(seg: Segmentation, n_filters: usize, rng: &mut Rng) -> ConvFilters {
        let k = seg.width * seg.width;
        // He-style init scaled to window size.
        let scale = (2.0 / k as f64).sqrt();
        let kernels = (0..n_filters)
            .map(|_| (0..k).map(|_| (rng.normal() * scale) as f32).collect())
            .collect();
        ConvFilters { seg, n_filters, kernels, bias: vec![0.0; n_filters] }
    }

    /// Flattened feature length: n_filters * out_side^2.
    pub fn out_len(&self, img_side: usize) -> usize {
        self.n_filters * self.seg.n_windows(img_side)
    }

    /// Forward: image -> flattened feature maps (filter-major), with ReLU.
    pub fn forward(&self, image: &[f32], img_side: usize) -> Vec<f32> {
        let windows = self.seg.windows(image, img_side);
        let mut out = Vec::with_capacity(self.out_len(img_side));
        for (f, kernel) in self.kernels.iter().enumerate() {
            for w in &windows {
                let mut acc = self.bias[f];
                for (k, x) in kernel.iter().zip(w.iter()) {
                    acc += k * x;
                }
                out.push(acc.max(0.0)); // ReLU
            }
        }
        out
    }

    /// Backward: given dL/d(features) for one image, accumulate kernel and
    /// bias gradients. Returns nothing for the input (images are leaves).
    pub fn backward(
        &self,
        image: &[f32],
        img_side: usize,
        features: &[f32],
        dl_dfeat: &[f32],
        grad_kernels: &mut [Vec<f32>],
        grad_bias: &mut [f32],
    ) {
        let windows = self.seg.windows(image, img_side);
        let n_w = windows.len();
        assert_eq!(dl_dfeat.len(), self.n_filters * n_w);
        for f in 0..self.n_filters {
            for (wi, w) in windows.iter().enumerate() {
                let idx = f * n_w + wi;
                // ReLU gate
                if features[idx] <= 0.0 {
                    continue;
                }
                let g = dl_dfeat[idx];
                if g == 0.0 {
                    continue;
                }
                for (k, x) in grad_kernels[f].iter_mut().zip(w.iter()) {
                    *k += g * x;
                }
                grad_bias[f] += g;
            }
        }
    }

    /// Flatten all parameters (kernels then biases) for the optimizer.
    pub fn params_mut(&mut self) -> Vec<&mut f32> {
        let mut v: Vec<&mut f32> = Vec::new();
        for k in &mut self.kernels {
            v.extend(k.iter_mut());
        }
        v.extend(self.bias.iter_mut());
        v
    }

    pub fn n_params(&self) -> usize {
        self.n_filters * (self.seg.width * self.seg.width + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry() {
        let seg = Segmentation::paper();
        assert_eq!(seg.out_side(IMG_SIDE), 13);
        assert_eq!(seg.n_windows(IMG_SIDE), 169);
        let mut rng = Rng::new(1);
        let conv = ConvFilters::paper(&mut rng);
        assert_eq!(conv.out_len(IMG_SIDE), 4 * 169);
        assert_eq!(conv.n_params(), 4 * 17);
    }

    #[test]
    fn windows_extract_expected_pixels() {
        // 6x6 image with pixel value = index; w=4, s=2 -> 2x2 windows.
        let img: Vec<f32> = (0..36).map(|i| i as f32).collect();
        let seg = Segmentation { width: 4, stride: 2 };
        let ws = seg.windows(&img, 6);
        assert_eq!(ws.len(), 4);
        assert_eq!(ws[0][0], 0.0); // top-left window starts at (0,0)
        assert_eq!(ws[1][0], 2.0); // next window starts at (0,2)
        assert_eq!(ws[2][0], 12.0); // second row of windows starts at (2,0)
        assert_eq!(ws[0][5], 7.0); // (1,1) within first window
    }

    #[test]
    fn forward_computes_relu_conv() {
        let seg = Segmentation { width: 2, stride: 2 };
        let mut rng = Rng::new(2);
        let mut conv = ConvFilters::new(seg, 1, &mut rng);
        conv.kernels[0] = vec![1.0, 0.0, 0.0, -1.0];
        conv.bias[0] = 0.0;
        // 4x4 image
        let img = vec![
            1.0, 2.0, 3.0, 4.0, //
            5.0, 6.0, 7.0, 8.0, //
            9.0, 1.0, 2.0, 3.0, //
            4.0, 5.0, 6.0, 7.0,
        ];
        let out = conv.forward(&img, 4);
        // windows: [(0,0)] 1*1 - 6 = -5 -> relu 0; [(0,2)] 3 - 8 = -5 -> 0;
        // [(2,0)] 9 - 5 = 4; [(2,2)] 2 - 7 = -5 -> 0
        assert_eq!(out, vec![0.0, 0.0, 4.0, 0.0]);
    }

    #[test]
    fn backward_matches_finite_difference() {
        let seg = Segmentation { width: 2, stride: 1 };
        let mut rng = Rng::new(3);
        let mut conv = ConvFilters::new(seg, 2, &mut rng);
        let img: Vec<f32> = (0..9).map(|i| (i as f32 / 4.0) - 1.0).collect(); // 3x3
        let feats = conv.forward(&img, 3);
        // loss = sum of features (dl/dfeat = 1)
        let dl: Vec<f32> = vec![1.0; feats.len()];
        let mut gk = vec![vec![0.0; 4]; 2];
        let mut gb = vec![0.0; 2];
        conv.backward(&img, 3, &feats, &dl, &mut gk, &mut gb);
        let eps = 1e-3f32;
        for f in 0..2 {
            for ki in 0..4 {
                let orig = conv.kernels[f][ki];
                conv.kernels[f][ki] = orig + eps;
                let lp: f32 = conv.forward(&img, 3).iter().sum();
                conv.kernels[f][ki] = orig - eps;
                let lm: f32 = conv.forward(&img, 3).iter().sum();
                conv.kernels[f][ki] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                assert!((gk[f][ki] - fd).abs() < 1e-2, "f{f} k{ki}: {} vs {fd}", gk[f][ki]);
            }
        }
    }

    #[test]
    fn deterministic_init() {
        let a = ConvFilters::paper(&mut Rng::new(9));
        let b = ConvFilters::paper(&mut Rng::new(9));
        assert_eq!(a.kernels, b.kernels);
    }
}
