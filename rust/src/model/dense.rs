//! Classical dense layer (Algorithm 1 line 11: y = W^T h + b).

use crate::util::Rng;

/// Fully-connected layer, row-major weights `[out][in]`.
#[derive(Debug, Clone)]
pub struct Dense {
    pub n_in: usize,
    pub n_out: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl Dense {
    pub fn new(n_in: usize, n_out: usize, rng: &mut Rng) -> Dense {
        let scale = (2.0 / n_in as f64).sqrt();
        Dense {
            n_in,
            n_out,
            w: (0..n_in * n_out).map(|_| (rng.normal() * scale) as f32).collect(),
            b: vec![0.0; n_out],
        }
    }

    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n_in);
        let mut y = self.b.clone();
        for o in 0..self.n_out {
            let row = &self.w[o * self.n_in..(o + 1) * self.n_in];
            let mut acc = 0.0f32;
            for (wi, xi) in row.iter().zip(x.iter()) {
                acc += wi * xi;
            }
            y[o] += acc;
        }
        y
    }

    /// Backward for one sample: given x and dL/dy, accumulate dL/dW and
    /// dL/db, and return dL/dx.
    pub fn backward(
        &self,
        x: &[f32],
        dl_dy: &[f32],
        grad_w: &mut [f32],
        grad_b: &mut [f32],
    ) -> Vec<f32> {
        assert_eq!(dl_dy.len(), self.n_out);
        let mut dl_dx = vec![0.0f32; self.n_in];
        for o in 0..self.n_out {
            let g = dl_dy[o];
            grad_b[o] += g;
            if g == 0.0 {
                continue;
            }
            let row = &self.w[o * self.n_in..(o + 1) * self.n_in];
            let grow = &mut grad_w[o * self.n_in..(o + 1) * self.n_in];
            for i in 0..self.n_in {
                grow[i] += g * x[i];
                dl_dx[i] += g * row[i];
            }
        }
        dl_dx
    }

    pub fn n_params(&self) -> usize {
        self.w.len() + self.b.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_is_affine() {
        let mut d = Dense::new(3, 2, &mut Rng::new(1));
        d.w = vec![1.0, 0.0, -1.0, 0.5, 0.5, 0.5];
        d.b = vec![0.25, -0.25];
        let y = d.forward(&[2.0, 4.0, 6.0]);
        assert!((y[0] - (2.0 - 6.0 + 0.25)).abs() < 1e-6);
        assert!((y[1] - (1.0 + 2.0 + 3.0 - 0.25)).abs() < 1e-6);
    }

    #[test]
    fn backward_matches_finite_difference() {
        let mut rng = Rng::new(7);
        let d = Dense::new(4, 3, &mut rng);
        let x: Vec<f32> = (0..4).map(|i| (i as f32) * 0.3 - 0.5).collect();
        // L = sum(y * coef)
        let coef = [0.7f32, -1.1, 0.4];
        let mut gw = vec![0.0; d.w.len()];
        let mut gb = vec![0.0; d.b.len()];
        let dl_dx = d.backward(&x, &coef, &mut gw, &mut gb);

        let loss = |d: &Dense, x: &[f32]| -> f32 {
            d.forward(x).iter().zip(coef.iter()).map(|(y, c)| y * c).sum()
        };
        let eps = 1e-3f32;
        // weight grads
        let mut d2 = d.clone();
        for wi in 0..d.w.len() {
            let orig = d2.w[wi];
            d2.w[wi] = orig + eps;
            let lp = loss(&d2, &x);
            d2.w[wi] = orig - eps;
            let lm = loss(&d2, &x);
            d2.w[wi] = orig;
            assert!((gw[wi] - (lp - lm) / (2.0 * eps)).abs() < 1e-2);
        }
        // input grads
        let mut x2 = x.clone();
        for xi in 0..x.len() {
            let orig = x2[xi];
            x2[xi] = orig + eps;
            let lp = loss(&d, &x2);
            x2[xi] = orig - eps;
            let lm = loss(&d, &x2);
            x2[xi] = orig;
            assert!((dl_dx[xi] - (lp - lm) / (2.0 * eps)).abs() < 1e-2);
        }
    }
}
