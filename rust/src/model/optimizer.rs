//! Optimizers over flat parameter vectors: SGD(+momentum) and Adam.

/// Optimizer choice + hyperparameters.
#[derive(Debug, Clone, Copy)]
pub enum Optimizer {
    Sgd { lr: f32, momentum: f32 },
    Adam { lr: f32, beta1: f32, beta2: f32, eps: f32 },
}

impl Optimizer {
    pub fn sgd(lr: f32) -> Optimizer {
        Optimizer::Sgd { lr, momentum: 0.0 }
    }

    pub fn adam(lr: f32) -> Optimizer {
        Optimizer::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    pub fn lr(&self) -> f32 {
        match *self {
            Optimizer::Sgd { lr, .. } | Optimizer::Adam { lr, .. } => lr,
        }
    }
}

/// Per-tensor optimizer state.
#[derive(Debug, Clone)]
pub struct OptState {
    opt: Optimizer,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl OptState {
    pub fn new(opt: Optimizer, n: usize) -> OptState {
        OptState { opt, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// Apply one gradient-descent step in place (`params -= update`).
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        match self.opt {
            Optimizer::Sgd { lr, momentum } => {
                for i in 0..params.len() {
                    self.m[i] = momentum * self.m[i] + grads[i];
                    params[i] -= lr * self.m[i];
                }
            }
            Optimizer::Adam { lr, beta1, beta2, eps } => {
                let bc1 = 1.0 - beta1.powi(self.t as i32);
                let bc2 = 1.0 - beta2.powi(self.t as i32);
                for i in 0..params.len() {
                    self.m[i] = beta1 * self.m[i] + (1.0 - beta1) * grads[i];
                    self.v[i] = beta2 * self.v[i] + (1.0 - beta2) * grads[i] * grads[i];
                    let mhat = self.m[i] / bc1;
                    let vhat = self.v[i] / bc2;
                    params[i] -= lr * mhat / (vhat.sqrt() + eps);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimize f(x) = (x - 3)^2 with each optimizer.
    fn minimize(opt: Optimizer, steps: usize) -> f32 {
        let mut x = vec![0.0f32];
        let mut st = OptState::new(opt, 1);
        for _ in 0..steps {
            let g = vec![2.0 * (x[0] - 3.0)];
            st.step(&mut x, &g);
        }
        x[0]
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let x = minimize(Optimizer::sgd(0.1), 100);
        assert!((x - 3.0).abs() < 1e-3, "x={x}");
    }

    #[test]
    fn sgd_momentum_converges() {
        let x = minimize(Optimizer::Sgd { lr: 0.05, momentum: 0.9 }, 200);
        assert!((x - 3.0).abs() < 1e-2, "x={x}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let x = minimize(Optimizer::adam(0.2), 300);
        assert!((x - 3.0).abs() < 1e-2, "x={x}");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut st = OptState::new(Optimizer::sgd(0.1), 2);
        let mut p = vec![0.0f32; 2];
        st.step(&mut p, &[1.0]);
    }
}
