//! The quantum-classical learning model (paper §III-A/B, Algorithm 1).
//!
//! Pipeline per image: Task Segmentation (conv filter windows) →
//! classical dense layer → rotation-encoder angles → QuClassi variational
//! fidelity circuit (one trained class-state per class) → softmax over
//! fidelities → cross-entropy loss. Quantum parameters train by
//! parameter-shift circuit banks (`circuit::bank`); classical parameters
//! train by chaining parameter-shift gradients of the *encoder angles*
//! through the dense/conv layers.
//!
//! Everything that executes circuits goes through the [`exec::CircuitExecutor`]
//! trait — the same model code runs on the local Rust simulator, the PJRT
//! artifact engine, or the full distributed cluster.

pub mod checkpoint;
pub mod dense;
pub mod exec;
pub mod optimizer;
pub mod quclassi;
pub mod segmentation;
pub mod trainer;

pub use exec::{CircuitExecutor, CountingExecutor, ParallelQsimExecutor, QsimExecutor};
pub use quclassi::QuClassiModel;
pub use trainer::{TrainConfig, TrainReport, Trainer};
