//! The QuClassi quantum-classical classifier.
//!
//! One variational "class state" per class; classification compares the
//! swap-test fidelity of the encoded input against each class state.
//! The classical front (conv filters + dense layer, Algorithm 1 lines
//! 8-11) maps an image to rotation-encoder angles.

use crate::error::DqError;
use crate::circuit::{CircuitBank, QuClassiConfig};
use crate::data::IMG_SIDE;
use crate::model::dense::Dense;
use crate::model::exec::{CircuitExecutor, CircuitPair};
use crate::model::segmentation::ConvFilters;
use crate::util::Rng;

const EPS: f32 = 1e-6;
const HALF_PI: f32 = std::f32::consts::FRAC_PI_2;

/// Loss family for training the class states.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LossKind {
    /// Softmax-over-fidelities cross-entropy: both class states receive
    /// coupled gradients each sample. Sharper boundaries, but the
    /// `f_A == f_B` saddle exists (rare seed-dependent collapse).
    #[default]
    Discriminative,
    /// QuClassi's original state-learning loss: `-ln f_match` — each
    /// class state only ever fits samples of its own class. Collapse-free
    /// (the states are decoupled), used by the ablation bench.
    Generative,
}

/// The full model: classical front + two variational class states.
#[derive(Debug, Clone)]
pub struct QuClassiModel {
    pub config: QuClassiConfig,
    /// theta[0] = class-A state parameters, theta[1] = class-B.
    pub theta: [Vec<f32>; 2],
    pub conv: ConvFilters,
    pub dense: Dense,
}

/// Forward-pass intermediate values (kept for backprop).
#[derive(Debug, Clone)]
pub struct Forward {
    pub features: Vec<f32>,
    pub pre_angles: Vec<f32>,
    pub angles: Vec<f32>,
}

/// Per-sample gradient bundle.
#[derive(Debug, Clone)]
pub struct SampleGrads {
    pub loss: f32,
    pub fid: [f32; 2],
    pub d_theta: [Vec<f32>; 2],
    /// dL/d(encoder angle); empty when classical training is disabled.
    pub d_angles: Vec<f32>,
    /// Circuits executed for this sample.
    pub circuits: usize,
}

impl QuClassiModel {
    /// Random initialization (paper: weights uniform in [0, pi]).
    pub fn new(config: QuClassiConfig, rng: &mut Rng) -> QuClassiModel {
        let n_p = config.n_params();
        let init = |rng: &mut Rng| -> Vec<f32> {
            (0..n_p).map(|_| (rng.f64() * std::f64::consts::PI) as f32).collect()
        };
        let conv = ConvFilters::paper(rng);
        let dense = Dense::new(conv.out_len(IMG_SIDE), config.n_features(), rng);
        QuClassiModel { config, theta: [init(rng), init(rng)], conv, dense }
    }

    /// Classical forward: image -> encoder angles in (0, pi).
    ///
    /// A sigmoid squashes the dense output into the injective encoder
    /// range; it is smooth, so the chain rule applies for classical
    /// backprop (unlike per-sample min/max normalization).
    pub fn forward_classical(&self, image: &[f32]) -> Forward {
        let features = self.conv.forward(image, IMG_SIDE);
        let pre_angles = self.dense.forward(&features);
        let angles = pre_angles
            .iter()
            .map(|&y| sigmoid(y) * std::f32::consts::PI)
            .collect();
        Forward { features, pre_angles, angles }
    }

    /// Fidelity of the encoded input against both class states.
    pub fn fidelities(
        &self,
        exec: &dyn CircuitExecutor,
        angles: &[f32],
    ) -> Result<[f32; 2], DqError> {
        let pairs: Vec<CircuitPair> = vec![
            (self.theta[0].clone(), angles.to_vec()),
            (self.theta[1].clone(), angles.to_vec()),
        ];
        let fids = exec.execute_bank(&self.config, &pairs)?;
        Ok([fids[0], fids[1]])
    }

    /// Class probability of class B: softmax over fidelities.
    pub fn prob_b(fid: [f32; 2]) -> f32 {
        (fid[1] + EPS) / (fid[0] + fid[1] + 2.0 * EPS)
    }

    /// Predict a class index (0 = A, 1 = B) for one image.
    pub fn predict(&self, exec: &dyn CircuitExecutor, image: &[f32]) -> Result<usize, DqError> {
        let fwd = self.forward_classical(image);
        let fid = self.fidelities(exec, &fwd.angles)?;
        Ok(if Self::prob_b(fid) > 0.5 { 1 } else { 0 })
    }

    /// Cross-entropy loss of one sample given its fidelities.
    pub fn loss(fid: [f32; 2], target: f32) -> f32 {
        let p = Self::prob_b(fid).clamp(1e-6, 1.0 - 1e-6);
        -(target * p.ln() + (1.0 - target) * (1.0 - p).ln())
    }

    /// Build the full circuit bank for one sample's gradient step and
    /// evaluate it through `exec`; returns loss + gradients.
    ///
    /// Bank layout: [bank_A | bank_B | data-shift entries (optional)].
    /// Every entry is an independent circuit — this is exactly the unit
    /// the co-Manager distributes (Algorithm 1 lines 12-22).
    pub fn sample_grads(
        &self,
        exec: &dyn CircuitExecutor,
        fwd: &Forward,
        target: f32,
        train_classical: bool,
    ) -> Result<SampleGrads, DqError> {
        self.sample_grads_with(exec, fwd, target, train_classical, LossKind::Discriminative)
    }

    /// [`QuClassiModel::sample_grads`] with an explicit loss family.
    pub fn sample_grads_with(
        &self,
        exec: &dyn CircuitExecutor,
        fwd: &Forward,
        target: f32,
        train_classical: bool,
        loss: LossKind,
    ) -> Result<SampleGrads, DqError> {
        let angles = &fwd.angles;
        let bank_a = CircuitBank::new(self.config, &self.theta[0]);
        let bank_b = CircuitBank::new(self.config, &self.theta[1]);
        let n_a = bank_a.len();
        let n_b = bank_b.len();
        let d = angles.len();

        let mut pairs: Vec<CircuitPair> = Vec::with_capacity(n_a + n_b + 4 * d);
        for e in bank_a.entries() {
            pairs.push((e.thetas.clone(), angles.clone()));
        }
        for e in bank_b.entries() {
            pairs.push((e.thetas.clone(), angles.clone()));
        }
        if train_classical {
            // Data-encoding gates are plain Ry/Rz: the two-term shift rule
            // is exact for encoder-angle gradients.
            for class in 0..2 {
                for j in 0..d {
                    let mut ap = angles.clone();
                    ap[j] += HALF_PI;
                    pairs.push((self.theta[class].clone(), ap));
                    let mut am = angles.clone();
                    am[j] -= HALF_PI;
                    pairs.push((self.theta[class].clone(), am));
                }
            }
        }

        let fids = exec.execute_bank(&self.config, &pairs)?;
        let (fid_a, grads_a) = bank_a.assemble(&fids[..n_a]);
        let (fid_b, grads_b) = bank_b.assemble(&fids[n_a..n_a + n_b]);
        let fid = [fid_a, fid_b];

        // dL/d(fidelity) per the chosen loss family.
        let (dl_dfa, dl_dfb, loss_value) = match loss {
            LossKind::Discriminative => {
                let p = Self::prob_b(fid).clamp(1e-6, 1.0 - 1e-6);
                let dl_dp = (p - target) / (p * (1.0 - p));
                let denom = (fid_a + fid_b + 2.0 * EPS).max(1e-6);
                let dp_dfa = -(fid_b + EPS) / (denom * denom);
                let dp_dfb = (fid_a + EPS) / (denom * denom);
                (dl_dp * dp_dfa, dl_dp * dp_dfb, Self::loss(fid, target))
            }
            LossKind::Generative => {
                // fit only the matching class state: L = -ln f_match
                let f_match = if target > 0.5 { fid_b } else { fid_a }.max(1e-4);
                let g = -1.0 / f_match;
                if target > 0.5 {
                    (0.0, g, -f_match.ln())
                } else {
                    (g, 0.0, -f_match.ln())
                }
            }
        };

        let d_theta_a: Vec<f32> = grads_a.iter().map(|g| dl_dfa * g).collect();
        let d_theta_b: Vec<f32> = grads_b.iter().map(|g| dl_dfb * g).collect();

        let mut d_angles = Vec::new();
        if train_classical {
            let base = n_a + n_b;
            d_angles = vec![0.0f32; d];
            for (class, dl_df) in [(0usize, dl_dfa), (1usize, dl_dfb)] {
                for j in 0..d {
                    let idx = base + class * 2 * d + 2 * j;
                    let df_dx = (fids[idx] - fids[idx + 1]) / 2.0;
                    d_angles[j] += dl_df * df_dx;
                }
            }
        }

        Ok(SampleGrads {
            loss: loss_value,
            fid,
            d_theta: [d_theta_a, d_theta_b],
            d_angles,
            circuits: pairs.len(),
        })
    }

    /// Backprop dL/d(angles) through sigmoid + dense + conv, accumulating
    /// classical gradients.
    pub fn classical_backward(
        &self,
        image: &[f32],
        fwd: &Forward,
        d_angles: &[f32],
        grad_dense_w: &mut [f32],
        grad_dense_b: &mut [f32],
        grad_kernels: &mut [Vec<f32>],
        grad_bias: &mut [f32],
    ) {
        // angles = pi * sigmoid(y)  =>  dangle/dy = pi * s(y)(1 - s(y))
        let dl_dy: Vec<f32> = fwd
            .pre_angles
            .iter()
            .zip(d_angles.iter())
            .map(|(&y, &da)| {
                let s = sigmoid(y);
                da * std::f32::consts::PI * s * (1.0 - s)
            })
            .collect();
        let dl_dfeat = self.dense.backward(&fwd.features, &dl_dy, grad_dense_w, grad_dense_b);
        self.conv
            .backward(image, IMG_SIDE, &fwd.features, &dl_dfeat, grad_kernels, grad_bias);
    }

    /// Circuits per full-gradient sample (for workload sizing).
    pub fn circuits_per_sample(&self, train_classical: bool) -> usize {
        let bank = CircuitBank::expected_len(&self.config);
        2 * bank + if train_classical { 4 * self.config.n_features() } else { 0 }
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::exec::QsimExecutor;

    fn tiny_image(rng: &mut Rng) -> Vec<f32> {
        (0..IMG_SIDE * IMG_SIDE).map(|_| rng.f32()).collect()
    }

    #[test]
    fn forward_angles_in_range() {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let mut rng = Rng::new(1);
        let m = QuClassiModel::new(cfg, &mut rng);
        let img = tiny_image(&mut rng);
        let fwd = m.forward_classical(&img);
        assert_eq!(fwd.angles.len(), cfg.n_features());
        for &a in &fwd.angles {
            assert!(a > 0.0 && a < std::f32::consts::PI);
        }
    }

    #[test]
    fn probabilities_are_complementary() {
        let p = QuClassiModel::prob_b([0.8, 0.4]);
        assert!(p < 0.5);
        let p2 = QuClassiModel::prob_b([0.2, 0.9]);
        assert!(p2 > 0.5);
        assert!((QuClassiModel::prob_b([0.5, 0.5]) - 0.5).abs() < 1e-5);
    }

    #[test]
    fn loss_decreases_along_gradient() {
        // One gradient step on theta must reduce the per-sample loss.
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let mut rng = Rng::new(5);
        let mut m = QuClassiModel::new(cfg, &mut rng);
        let img = tiny_image(&mut rng);
        let fwd = m.forward_classical(&img);
        let exec = QsimExecutor;
        let g = m.sample_grads(&exec, &fwd, 1.0, false).unwrap();
        let lr = 0.1f32;
        for p in 0..m.theta[0].len() {
            m.theta[0][p] -= lr * g.d_theta[0][p];
            m.theta[1][p] -= lr * g.d_theta[1][p];
        }
        let fid2 = m.fidelities(&exec, &fwd.angles).unwrap();
        let loss2 = QuClassiModel::loss(fid2, 1.0);
        assert!(loss2 < g.loss, "loss {} -> {}", g.loss, loss2);
    }

    #[test]
    fn classical_gradient_direction_reduces_loss() {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let mut rng = Rng::new(8);
        let mut m = QuClassiModel::new(cfg, &mut rng);
        let img = tiny_image(&mut rng);
        let exec = QsimExecutor;
        let fwd = m.forward_classical(&img);
        let g = m.sample_grads(&exec, &fwd, 0.0, true).unwrap();
        assert_eq!(g.d_angles.len(), cfg.n_features());
        let mut gw = vec![0.0; m.dense.w.len()];
        let mut gb = vec![0.0; m.dense.b.len()];
        let mut gk = vec![vec![0.0; 16]; m.conv.n_filters];
        let mut gbias = vec![0.0; m.conv.n_filters];
        m.classical_backward(&img, &fwd, &g.d_angles, &mut gw, &mut gb, &mut gk, &mut gbias);
        // take a small classical step
        let lr = 0.05f32;
        for (w, gw) in m.dense.w.iter_mut().zip(gw.iter()) {
            *w -= lr * gw;
        }
        for (b, gb) in m.dense.b.iter_mut().zip(gb.iter()) {
            *b -= lr * gb;
        }
        let fwd2 = m.forward_classical(&img);
        let fid2 = m.fidelities(&exec, &fwd2.angles).unwrap();
        let loss2 = QuClassiModel::loss(fid2, 0.0);
        assert!(loss2 <= g.loss + 1e-5, "loss {} -> {}", g.loss, loss2);
    }

    #[test]
    fn generative_loss_updates_only_matching_state() {
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let mut rng = Rng::new(13);
        let m = QuClassiModel::new(cfg, &mut rng);
        let img = tiny_image(&mut rng);
        let fwd = m.forward_classical(&img);
        let g = m
            .sample_grads_with(&QsimExecutor, &fwd, 1.0, false, LossKind::Generative)
            .unwrap();
        assert!(g.d_theta[0].iter().all(|&x| x == 0.0), "class-A state must be untouched");
        assert!(g.d_theta[1].iter().any(|&x| x != 0.0), "class-B state must learn");
        // gradient direction increases the matching fidelity
        let mut m2 = m.clone();
        for p in 0..m2.theta[1].len() {
            m2.theta[1][p] -= 0.1 * g.d_theta[1][p];
        }
        let f_before = m.fidelities(&QsimExecutor, &fwd.angles).unwrap()[1];
        let f_after = m2.fidelities(&QsimExecutor, &fwd.angles).unwrap()[1];
        assert!(f_after > f_before, "{f_after} !> {f_before}");
    }

    #[test]
    fn circuits_per_sample_accounting() {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let mut rng = Rng::new(2);
        let m = QuClassiModel::new(cfg, &mut rng);
        // bank = 1 + 2*4 = 9 per class; classical adds 4*4 = 16
        assert_eq!(m.circuits_per_sample(false), 18);
        assert_eq!(m.circuits_per_sample(true), 34);
        // verify against an actual execution count
        let exec = crate::model::exec::CountingExecutor::new(QsimExecutor);
        let img = tiny_image(&mut rng);
        let fwd = m.forward_classical(&img);
        let g = m.sample_grads(&exec, &fwd, 1.0, true).unwrap();
        assert_eq!(g.circuits, 34);
        assert_eq!(exec.circuits(), 34);
    }
}
