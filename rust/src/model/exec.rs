//! The circuit-execution boundary.
//!
//! A [`CircuitExecutor`] evaluates a batch of independent QuClassi
//! circuits — (theta vector, data vector) pairs under one configuration —
//! and returns their swap-test fidelities. Implementations:
//!
//! * [`QsimExecutor`] — the in-process Rust statevector simulator
//!   (baseline / fallback path).
//! * [`ParallelQsimExecutor`] — the same simulator striped across a
//!   scoped thread pool (bitwise-identical results, parallel wall-clock).
//! * `runtime::PjrtEngine` — the AOT JAX/Pallas artifact via PJRT
//!   (production path).
//! * `cluster::ClusterClient` — submits to the distributed co-Manager
//!   (the paper's system).

use crate::circuit::{builder, QuClassiConfig};
use crate::error::DqError;
use crate::qsim::compile::{CacheStats, CompiledProgram, PlanCache};
use crate::qsim::State;

/// One circuit = one (thetas, data) pair under a configuration.
pub type CircuitPair = (Vec<f32>, Vec<f32>);

/// Evaluates banks of independent circuits.
pub trait CircuitExecutor: Send + Sync {
    /// Execute every pair; returns one fidelity per pair, same order.
    fn execute_bank(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError>;

    /// Human-readable executor description (for logs/reports).
    fn describe(&self) -> String {
        "executor".to_string()
    }
}

/// Local Rust statevector execution through the compiled-circuit
/// pipeline: the plan comes from the process-wide config-keyed cache
/// ([`builder::compile_quclassi`]), each pair only rebinds parameters
/// into a reused bound program, and one scratch statevector is reset
/// per circuit — no per-circuit gate-list build, plan scan, or
/// allocation (DESIGN.md §15).
#[derive(Debug, Default)]
pub struct QsimExecutor;

impl CircuitExecutor for QsimExecutor {
    fn execute_bank(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        let program = builder::compile_quclassi(config);
        let mut bound = program.bind_skeleton();
        let mut scratch = State::zero(config.qubits);
        Ok(pairs
            .iter()
            .map(|(thetas, data)| {
                program.rebind(&mut bound, thetas, data);
                bound.fidelity_into(&mut scratch) as f32
            })
            .collect())
    }

    fn describe(&self) -> String {
        "qsim (rust statevector, compiled plans)".to_string()
    }
}

/// Rust statevector execution fanned across a scoped worker-thread pool.
///
/// Circuits in a bank are independent, so the bank is striped across
/// `threads` OS threads via [`crate::util::pool::parallel_indexed`].
/// Plans come from a per-executor [`PlanCache`]; every circuit binds
/// parameters into the shared compiled plan and runs the same blocked
/// kernels as [`QsimExecutor`]'s serial loop, which keeps the output
/// **bitwise identical** to [`QsimExecutor`] — only wall-clock changes.
/// This is the worker-side lever behind the paper's circuits-per-second
/// scaling (DESIGN.md §11).
#[derive(Debug)]
pub struct ParallelQsimExecutor {
    threads: usize,
    cache: PlanCache<QuClassiConfig>,
}

impl ParallelQsimExecutor {
    /// Pool with a fixed thread budget (clamped to at least 1).
    pub fn new(threads: usize) -> ParallelQsimExecutor {
        ParallelQsimExecutor { threads: threads.max(1), cache: PlanCache::new(16) }
    }

    /// Pool sized to the host's available parallelism.
    pub fn auto() -> ParallelQsimExecutor {
        ParallelQsimExecutor::new(detect_threads())
    }

    /// The configured thread budget.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Hit/miss/occupancy counters of this executor's plan cache.
    pub fn plan_cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }
}

/// Host thread budget (1 when the query fails).
pub fn detect_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl CircuitExecutor for ParallelQsimExecutor {
    fn execute_bank(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        let program = self
            .cache
            .get_or_compile(config, || {
                CompiledProgram::compile(builder::build_quclassi_template(config))
            });
        Ok(crate::util::pool::parallel_indexed(pairs.len(), self.threads, |i| {
            let (thetas, data) = &pairs[i];
            // bind == skeleton + rebind, so a fresh per-circuit bind is
            // bitwise identical to the serial executor's rebind loop.
            program.bind(thetas, data).fidelity() as f32
        }))
    }

    fn describe(&self) -> String {
        format!("qsim-par (rust statevector, compiled plans, {} threads)", self.threads)
    }
}

/// Wrapper that counts circuits and batches (metrics for the paper's
/// circuits-per-second evaluation).
pub struct CountingExecutor<E> {
    inner: E,
    circuits: std::sync::atomic::AtomicU64,
    batches: std::sync::atomic::AtomicU64,
}

impl<E> CountingExecutor<E> {
    pub fn new(inner: E) -> CountingExecutor<E> {
        CountingExecutor {
            inner,
            circuits: std::sync::atomic::AtomicU64::new(0),
            batches: std::sync::atomic::AtomicU64::new(0),
        }
    }

    pub fn circuits(&self) -> u64 {
        self.circuits.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl<E: CircuitExecutor> CircuitExecutor for CountingExecutor<E> {
    fn execute_bank(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        self.circuits
            .fetch_add(pairs.len() as u64, std::sync::atomic::Ordering::Relaxed);
        self.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.execute_bank(config, pairs)
    }

    fn describe(&self) -> String {
        format!("counting({})", self.inner.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn qsim_executor_matches_direct_simulation() {
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let mut rng = Rng::new(3);
        let pairs: Vec<CircuitPair> = (0..8)
            .map(|_| {
                (
                    (0..cfg.n_params()).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect(),
                    (0..cfg.n_features()).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect(),
                )
            })
            .collect();
        let fids = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
        for (i, (t, d)) in pairs.iter().enumerate() {
            let want = builder::simulate_fidelity(&cfg, t, d);
            // compiled plans re-associate the float products; 1e-6 covers
            // the f32 rounding of the ~1e-15 f64 drift with margin
            assert!((fids[i] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn parallel_executor_caches_plans_per_instance() {
        let cfg = QuClassiConfig::new(5, 3).unwrap();
        let exec = ParallelQsimExecutor::new(2);
        let pair = (vec![0.3f32; cfg.n_params()], vec![0.1f32; cfg.n_features()]);
        exec.execute_bank(&cfg, &[pair.clone()]).unwrap();
        let first = exec.plan_cache_stats();
        assert_eq!(first.misses, 1);
        assert_eq!(first.len, 1);
        exec.execute_bank(&cfg, &[pair]).unwrap();
        let second = exec.plan_cache_stats();
        assert_eq!(second.hits, first.hits + 1);
        assert_eq!(second.misses, 1, "repeat config must not recompile");
    }

    #[test]
    fn counting_executor_accumulates() {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let exec = CountingExecutor::new(QsimExecutor);
        let pair = (vec![0.1f32; 4], vec![0.2f32; 4]);
        exec.execute_bank(&cfg, &[pair.clone(), pair.clone()]).unwrap();
        exec.execute_bank(&cfg, &[pair]).unwrap();
        assert_eq!(exec.circuits(), 3);
        assert_eq!(exec.batches(), 2);
    }

    #[test]
    fn empty_bank_is_fine() {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        assert_eq!(QsimExecutor.execute_bank(&cfg, &[]).unwrap().len(), 0);
        assert_eq!(ParallelQsimExecutor::new(4).execute_bank(&cfg, &[]).unwrap().len(), 0);
    }

    #[test]
    fn parallel_executor_is_bitwise_identical_to_serial() {
        let cfg = QuClassiConfig::new(7, 3).unwrap();
        let mut rng = Rng::new(21);
        let pairs: Vec<CircuitPair> = (0..23)
            .map(|_| {
                (
                    (0..cfg.n_params()).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect(),
                    (0..cfg.n_features()).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect(),
                )
            })
            .collect();
        let serial = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
        for threads in [1usize, 2, 4, 16] {
            let parallel = ParallelQsimExecutor::new(threads).execute_bank(&cfg, &pairs).unwrap();
            assert_eq!(serial, parallel, "threads={threads}");
        }
    }

    #[test]
    fn thread_budget_is_clamped_and_reported() {
        assert_eq!(ParallelQsimExecutor::new(0).threads(), 1);
        assert_eq!(ParallelQsimExecutor::new(6).threads(), 6);
        assert!(ParallelQsimExecutor::auto().threads() >= 1);
        assert!(ParallelQsimExecutor::new(2).describe().contains("2 threads"));
    }
}
