//! Model checkpointing: save/load a trained [`QuClassiModel`] as JSON.
//!
//! The wire substrate doubles as the serialization layer, so checkpoints
//! are human-readable and diffable. Versioned for forward compatibility.

use std::path::Path;

use crate::circuit::QuClassiConfig;
use crate::data::IMG_SIDE;
use crate::model::dense::Dense;
use crate::model::quclassi::QuClassiModel;
use crate::model::segmentation::{ConvFilters, Segmentation};
use crate::wire::{self, Value};

const FORMAT_VERSION: u64 = 1;

/// Serialize a model to a JSON value.
pub fn to_value(model: &QuClassiModel) -> Value {
    let kernels: Vec<Value> =
        model.conv.kernels.iter().map(|k| Value::from(k.as_slice())).collect();
    Value::obj()
        .with("format", FORMAT_VERSION)
        .with("qubits", model.config.qubits)
        .with("layers", model.config.layers)
        .with("theta_a", model.theta[0].as_slice())
        .with("theta_b", model.theta[1].as_slice())
        .with(
            "conv",
            Value::obj()
                .with("width", model.conv.seg.width)
                .with("stride", model.conv.seg.stride)
                .with("n_filters", model.conv.n_filters)
                .with("kernels", Value::Arr(kernels))
                .with("bias", model.conv.bias.as_slice()),
        )
        .with(
            "dense",
            Value::obj()
                .with("n_in", model.dense.n_in)
                .with("n_out", model.dense.n_out)
                .with("w", model.dense.w.as_slice())
                .with("b", model.dense.b.as_slice()),
        )
}

/// Deserialize a model from a JSON value.
pub fn from_value(v: &Value) -> Result<QuClassiModel, String> {
    let version = v.req_u64("format")?;
    if version != FORMAT_VERSION {
        return Err(format!("unsupported checkpoint format {version}"));
    }
    let config = QuClassiConfig::new(v.req_usize("qubits")?, v.req_usize("layers")?)?;
    let theta_a = v.req_f32_vec("theta_a")?;
    let theta_b = v.req_f32_vec("theta_b")?;
    if theta_a.len() != config.n_params() || theta_b.len() != config.n_params() {
        return Err("checkpoint theta arity mismatch".to_string());
    }

    let conv_v = v.get("conv").ok_or("missing conv")?;
    let seg = Segmentation {
        width: conv_v.req_usize("width")?,
        stride: conv_v.req_usize("stride")?,
    };
    let n_filters = conv_v.req_usize("n_filters")?;
    let kernels: Result<Vec<Vec<f32>>, String> = conv_v
        .req_arr("kernels")?
        .iter()
        .map(|k| {
            k.as_arr()
                .ok_or_else(|| "kernel not an array".to_string())?
                .iter()
                .map(|x| x.as_f64().map(|f| f as f32).ok_or_else(|| "bad kernel value".to_string()))
                .collect()
        })
        .collect();
    let kernels = kernels?;
    if kernels.len() != n_filters {
        return Err("kernel count mismatch".to_string());
    }
    let conv = ConvFilters { seg, n_filters, kernels, bias: conv_v.req_f32_vec("bias")? };

    let dense_v = v.get("dense").ok_or("missing dense")?;
    let dense = Dense {
        n_in: dense_v.req_usize("n_in")?,
        n_out: dense_v.req_usize("n_out")?,
        w: dense_v.req_f32_vec("w")?,
        b: dense_v.req_f32_vec("b")?,
    };
    if dense.w.len() != dense.n_in * dense.n_out {
        return Err("dense weight arity mismatch".to_string());
    }
    if dense.n_in != conv.out_len(IMG_SIDE) {
        return Err("dense input does not match conv output".to_string());
    }
    if dense.n_out != config.n_features() {
        return Err("dense output does not match encoder width".to_string());
    }

    Ok(QuClassiModel { config, theta: [theta_a, theta_b], conv, dense })
}

/// Save to a file (pretty-printed JSON).
pub fn save(model: &QuClassiModel, path: &Path) -> Result<(), String> {
    std::fs::write(path, wire::to_string_pretty(&to_value(model)))
        .map_err(|e| format!("write {}: {e}", path.display()))
}

/// Load from a file.
pub fn load(path: &Path) -> Result<QuClassiModel, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    let v = wire::parse(&text).map_err(|e| format!("checkpoint json: {e}"))?;
    from_value(&v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::exec::QsimExecutor;
    use crate::util::Rng;

    fn model() -> QuClassiModel {
        QuClassiModel::new(QuClassiConfig::new(5, 2).unwrap(), &mut Rng::new(4))
    }

    #[test]
    fn round_trip_preserves_everything() {
        let m = model();
        let back = from_value(&to_value(&m)).unwrap();
        assert_eq!(m.config, back.config);
        assert_eq!(m.theta[0], back.theta[0]);
        assert_eq!(m.theta[1], back.theta[1]);
        assert_eq!(m.conv.kernels, back.conv.kernels);
        assert_eq!(m.dense.w, back.dense.w);
    }

    #[test]
    fn round_trip_preserves_predictions() {
        let m = model();
        let back = from_value(&to_value(&m)).unwrap();
        let mut rng = Rng::new(5);
        let img: Vec<f32> = (0..IMG_SIDE * IMG_SIDE).map(|_| rng.f32()).collect();
        let a = m.predict(&QsimExecutor, &img).unwrap();
        let b = back.predict(&QsimExecutor, &img).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn file_round_trip() {
        let m = model();
        let path = std::env::temp_dir().join("dqulearn_ckpt_test.json");
        save(&m, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(m.theta[0], back.theta[0]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_corrupt_checkpoints() {
        let m = model();
        let mut v = to_value(&m);
        v.set("format", 99u64);
        assert!(from_value(&v).is_err());

        let mut v2 = to_value(&m);
        v2.set("theta_a", vec![0.0f32; 2].as_slice());
        assert!(from_value(&v2).is_err());

        assert!(from_value(&wire::parse("{}").unwrap()).is_err());
    }
}
