//! Algorithm 1 — the DQuLearn training driver.
//!
//! Epoch loop with per-epoch timers (lines 5, 24-25), per-sample circuit
//! banks submitted through a [`CircuitExecutor`] (lines 12-22 — the
//! executor is where distribution happens), gradient assembly, optimizer
//! updates, and per-epoch accuracy (line 26).

use crate::data::Dataset;
use crate::error::DqError;
use crate::model::exec::CircuitExecutor;
use crate::model::optimizer::{OptState, Optimizer};
use crate::model::quclassi::{LossKind, QuClassiModel};
use crate::util::Rng;

/// Training hyperparameters (defaults follow the paper's settings where
/// it states them: lr = 0.001, epochs = 40 for accuracy runs).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub optimizer: Optimizer,
    /// Also train the conv + dense front (adds 4·D circuits per sample).
    pub train_classical: bool,
    /// Classical-layer learning-rate multiplier relative to the quantum
    /// optimizer (classical params see far noisier per-sample gradients —
    /// a 0.1x rate prevents the encoder from collapsing to a constant).
    pub classical_lr_scale: f32,
    pub seed: u64,
    /// Stop early once train accuracy reaches this (None = run all epochs).
    pub early_stop_acc: Option<f64>,
    /// Loss family (see [`LossKind`]).
    pub loss: LossKind,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 40,
            optimizer: Optimizer::adam(0.05),
            train_classical: false,
            classical_lr_scale: 0.1,
            seed: 0xD0_1EA2,
            early_stop_acc: None,
            loss: LossKind::Discriminative,
        }
    }
}

/// Per-epoch record (the paper's Figures plot these).
#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub epoch: usize,
    pub wall_seconds: f64,
    pub mean_loss: f64,
    pub train_accuracy: f64,
    pub circuits: usize,
}

/// Full training report.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub epochs: Vec<EpochRecord>,
    pub test_accuracy: f64,
    pub total_circuits: usize,
    pub total_seconds: f64,
}

impl TrainReport {
    pub fn final_train_accuracy(&self) -> f64 {
        self.epochs.last().map(|e| e.train_accuracy).unwrap_or(0.0)
    }

    pub fn circuits_per_second(&self) -> f64 {
        self.total_circuits as f64 / self.total_seconds.max(1e-9)
    }
}

/// Algorithm-1 trainer.
pub struct Trainer {
    pub config: TrainConfig,
}

impl Trainer {
    pub fn new(config: TrainConfig) -> Trainer {
        Trainer { config }
    }

    /// Train `model` on `dataset` through `exec`.
    pub fn train(
        &self,
        model: &mut QuClassiModel,
        dataset: &Dataset,
        exec: &dyn CircuitExecutor,
    ) -> Result<TrainReport, DqError> {
        let mut rng = Rng::new(self.config.seed);
        let mut opt_a = OptState::new(self.config.optimizer, model.theta[0].len());
        let mut opt_b = OptState::new(self.config.optimizer, model.theta[1].len());
        // Classical layers always use plain SGD: adaptive optimizers
        // normalize away the (tiny, noisy) per-sample gradient magnitudes
        // and walk the dense layer into sigmoid saturation, collapsing the
        // encoder to a constant (observed empirically; see DESIGN.md §9).
        let classical_opt = Optimizer::Sgd {
            lr: self.config.optimizer.lr() * self.config.classical_lr_scale,
            momentum: 0.0,
        };
        let mut opt_dense =
            OptState::new(classical_opt, model.dense.w.len() + model.dense.b.len());
        let mut opt_conv = OptState::new(
            classical_opt,
            model.conv.n_filters * (model.conv.seg.width * model.conv.seg.width + 1),
        );

        let mut epochs = Vec::new();
        let mut total_circuits = 0usize;
        let t0 = std::time::Instant::now();
        let mut order: Vec<usize> = (0..dataset.train.len()).collect();

        for epoch in 0..self.config.epochs {
            let epoch_start = std::time::Instant::now(); // line 5: epoch timer
            rng.shuffle(&mut order);
            let mut loss_acc = 0.0f64;
            let mut circuits = 0usize;

            for &i in &order {
                let ex = &dataset.train[i];
                let target = dataset.target(ex);
                let fwd = model.forward_classical(&ex.pixels);
                let grads = model.sample_grads_with(
                    exec,
                    &fwd,
                    target,
                    self.config.train_classical,
                    self.config.loss,
                )?;
                loss_acc += grads.loss as f64;
                circuits += grads.circuits;

                // quantum updates (parameter-shift gradients)
                opt_a.step(&mut model.theta[0], &grads.d_theta[0]);
                opt_b.step(&mut model.theta[1], &grads.d_theta[1]);

                // classical updates (chained through encoder-angle shifts)
                if self.config.train_classical {
                    let mut gw = vec![0.0f32; model.dense.w.len()];
                    let mut gb = vec![0.0f32; model.dense.b.len()];
                    let kparams = model.conv.seg.width * model.conv.seg.width;
                    let mut gk = vec![vec![0.0f32; kparams]; model.conv.n_filters];
                    let mut gbias = vec![0.0f32; model.conv.n_filters];
                    model.classical_backward(
                        &ex.pixels,
                        &fwd,
                        &grads.d_angles,
                        &mut gw,
                        &mut gb,
                        &mut gk,
                        &mut gbias,
                    );
                    // flatten dense grads
                    let mut dense_params: Vec<f32> =
                        model.dense.w.iter().chain(model.dense.b.iter()).copied().collect();
                    let dense_grads: Vec<f32> = gw.iter().chain(gb.iter()).copied().collect();
                    opt_dense.step(&mut dense_params, &dense_grads);
                    let (w_new, b_new) = dense_params.split_at(model.dense.w.len());
                    model.dense.w.copy_from_slice(w_new);
                    model.dense.b.copy_from_slice(b_new);
                    // flatten conv grads
                    let mut conv_params: Vec<f32> = model
                        .conv
                        .kernels
                        .iter()
                        .flatten()
                        .chain(model.conv.bias.iter())
                        .copied()
                        .collect();
                    let conv_grads: Vec<f32> =
                        gk.iter().flatten().chain(gbias.iter()).copied().collect();
                    opt_conv.step(&mut conv_params, &conv_grads);
                    let mut off = 0;
                    for k in &mut model.conv.kernels {
                        k.copy_from_slice(&conv_params[off..off + kparams]);
                        off += kparams;
                    }
                    model.conv.bias.copy_from_slice(&conv_params[off..]);
                }
            }

            let train_accuracy = self.accuracy(model, exec, dataset, true)?;
            let rec = EpochRecord {
                epoch,
                wall_seconds: epoch_start.elapsed().as_secs_f64(), // line 25
                mean_loss: loss_acc / dataset.train.len().max(1) as f64,
                train_accuracy,
                circuits,
            };
            crate::log_debug!(
                "trainer",
                "epoch {epoch}: loss={:.4} acc={:.3} circuits={circuits} ({:.2}s)",
                rec.mean_loss,
                rec.train_accuracy,
                rec.wall_seconds
            );
            total_circuits += circuits;
            epochs.push(rec);
            if let Some(stop) = self.config.early_stop_acc {
                if train_accuracy >= stop {
                    break;
                }
            }
        }

        let test_accuracy = self.accuracy(model, exec, dataset, false)?;
        Ok(TrainReport {
            epochs,
            test_accuracy,
            total_circuits,
            total_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Accuracy over the train or test split.
    pub fn accuracy(
        &self,
        model: &QuClassiModel,
        exec: &dyn CircuitExecutor,
        dataset: &Dataset,
        train_split: bool,
    ) -> Result<f64, DqError> {
        let split = if train_split { &dataset.train } else { &dataset.test };
        if split.is_empty() {
            return Ok(0.0);
        }
        let mut correct = 0usize;
        for ex in split {
            let pred = model.predict(exec, &ex.pixels)?;
            let want = if dataset.target(ex) > 0.5 { 1 } else { 0 };
            if pred == want {
                correct += 1;
            }
        }
        Ok(correct as f64 / split.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::QuClassiConfig;
    use crate::model::exec::{CountingExecutor, QsimExecutor};

    fn toy_dataset() -> Dataset {
        Dataset::binary_pair(None, 3, 9, 12, 77)
    }

    #[test]
    fn training_reduces_loss_and_learns() {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let mut rng = Rng::new(42);
        let mut model = QuClassiModel::new(cfg, &mut rng);
        let ds = toy_dataset();
        let exec = QsimExecutor;
        let trainer = Trainer::new(TrainConfig {
            epochs: 10,
            optimizer: Optimizer::adam(0.05),
            train_classical: true,
            classical_lr_scale: 0.1,
            seed: 7,
            early_stop_acc: None,
            loss: LossKind::Discriminative,
        });
        let report = trainer.train(&mut model, &ds, &exec).unwrap();
        assert_eq!(report.epochs.len(), 10);
        let first = report.epochs.first().unwrap().mean_loss;
        let last = report.epochs.last().unwrap().mean_loss;
        assert!(last < first, "loss did not decrease: {first} -> {last}");
        assert!(
            report.final_train_accuracy() >= 0.7,
            "accuracy too low: {}",
            report.final_train_accuracy()
        );
    }

    #[test]
    fn circuit_accounting_is_consistent() {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let mut rng = Rng::new(1);
        let mut model = QuClassiModel::new(cfg, &mut rng);
        let ds = toy_dataset();
        let exec = CountingExecutor::new(QsimExecutor);
        let trainer = Trainer::new(TrainConfig {
            epochs: 1,
            optimizer: Optimizer::sgd(0.05),
            train_classical: false,
            classical_lr_scale: 0.1,
            seed: 3,
            early_stop_acc: None,
            loss: LossKind::Discriminative,
        });
        let report = trainer.train(&mut model, &ds, &exec).unwrap();
        // per sample: 2 banks of 9 = 18 circuits
        let expected_train = 18 * ds.train.len();
        assert_eq!(report.total_circuits, expected_train);
        // counting executor additionally saw accuracy-evaluation circuits
        assert!(exec.circuits() as usize > expected_train);
    }

    #[test]
    fn early_stopping_works() {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let mut rng = Rng::new(2);
        let mut model = QuClassiModel::new(cfg, &mut rng);
        let ds = toy_dataset();
        let trainer = Trainer::new(TrainConfig {
            epochs: 50,
            optimizer: Optimizer::adam(0.1),
            train_classical: true,
            classical_lr_scale: 0.1,
            seed: 5,
            early_stop_acc: Some(0.75),
            loss: LossKind::Discriminative,
        });
        let report = trainer.train(&mut model, &ds, &QsimExecutor).unwrap();
        assert!(report.epochs.len() < 50, "early stop never triggered");
    }
}
